from repro.ckpt.checkpoint import load_pytree, restore_latest, save_pytree

__all__ = ["load_pytree", "restore_latest", "save_pytree"]
