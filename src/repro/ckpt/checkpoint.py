"""Flat-key npz pytree checkpointing (no external deps).

Leaves are saved under '/'-joined key paths; restore rebuilds against a
template pytree so dtypes/structure are validated, and arrays are placed on
the template's shardings when one is supplied (multi-host restore).
"""
from __future__ import annotations

import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

Params = Any


def _flatten(tree: Params):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(re.sub(r"[\[\]'.]", "", str(p)) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub":      # bf16 etc: not numpy-native
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def save_pytree(path: str, tree: Params, step: Optional[int] = None) -> str:
    if step is not None:
        path = os.path.join(path, f"step_{step:08d}.npz")
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flatten(tree))
    return path


def load_pytree(path: str, template: Params) -> Params:
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = "/".join(re.sub(r"[\[\]'.]", "", str(x)) for x in p)
        arr = data[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: ckpt {arr.shape} != template {leaf.shape}")
        sharding = getattr(leaf, "sharding", None)
        arr = jax.device_put(jnp.asarray(arr).astype(leaf.dtype), sharding)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_latest(ckpt_dir: str, template: Params):
    if not os.path.isdir(ckpt_dir):
        return None, -1
    files = sorted(f for f in os.listdir(ckpt_dir)
                   if f.startswith("step_") and f.endswith(".npz"))
    if not files:
        return None, -1
    step = int(files[-1][5:-4])
    return load_pytree(os.path.join(ckpt_dir, files[-1]), template), step
