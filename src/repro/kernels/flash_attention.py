"""Flash attention Pallas TPU kernel (causal / sliding-window / bidirectional).

Online-softmax attention with the canonical TPU grid layout:
  grid = (batch*heads, q_blocks, kv_blocks); the kv dimension is the
  innermost, sequentially-iterated ("arbitrary") axis, and the running
  (m, l, acc) state lives in VMEM scratch that persists across kv steps.
Q/K/V tiles are (block_q, d) / (block_k, d) VMEM blocks; d is the full
head dim (MXU-aligned when d in {64, 128}).  Scores never touch HBM —
the whole point versus the XLA einsum path (ref.py).

K/V must be pre-expanded to the full query head count (GQA repeat happens
in ops.flash_attention, as in models/attention.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, window: int, block_q: int, block_k: int,
            num_kv_blocks: int, sm_scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), dtype=bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window:
        mask = mask & (k_pos > q_pos - window)

    # skip fully-masked tiles (above the causal diagonal / outside window)
    run = True
    if causal:
        run = ki * block_k <= qi * block_q + block_q - 1
    if window:
        run = jnp.logical_and(
            run, (ki + 1) * block_k - 1 > qi * block_q - window)

    @pl.when(run)
    def _step():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_cur

    @pl.when(ki == num_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=0,
                    block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K,
                    interpret=True):
    """q, k, v: (BH, S, D) with identical head counts.  Returns (BH, S, D).

    ``interpret=True`` executes the kernel body in Python on CPU (this
    container); on real TPUs pass interpret=False.
    """
    bh, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    nq, nk = s // block_q, s // block_k
    sm_scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _kernel, causal=causal, window=window, block_q=block_q,
        block_k=block_k, num_kv_blocks=nk, sm_scale=sm_scale)

    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # running max m
            pltpu.VMEM((block_q,), jnp.float32),       # running sum l
            pltpu.VMEM((block_q, d), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
