"""Fill-aggregation (paper Algorithm 3) Pallas TPU kernel.

The server-side hot loop: for every parameter element,
    out = sum_k w_k * (mask_k * client_k + (1 - mask_k) * prev)
over m client uploads.  Pure memory-bound elementwise reduction over
(m x P) bytes; tiled (m, block_p) so each VMEM tile is reused across the
m-way reduction, with the (8, 128)-aligned block on the last axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 64 * 128


def _kernel(c_ref, m_ref, w_ref, p_ref, o_ref):
    prev = p_ref[...].astype(jnp.float32)       # (block,)
    cl = c_ref[...].astype(jnp.float32)         # (m, block)
    mk = m_ref[...].astype(jnp.float32)         # (m, block)
    w = w_ref[...].astype(jnp.float32)          # (m,)
    filled = mk * cl + (1.0 - mk) * prev[None, :]
    o_ref[...] = jnp.sum(w[:, None] * filled, axis=0).astype(o_ref.dtype)


def fill_aggregate(clients, masks, weights, prev, *, block=DEFAULT_BLOCK,
                   interpret=True, donate_prev=False):
    """clients, masks: (m, P); weights: (m,); prev: (P,) -> (P,).

    ``donate_prev`` aliases the ``prev`` buffer into the output
    (``input_output_aliases``): grid step i reads prev's block i before
    writing out's block i and blocks never overlap, so the master update
    can reuse the previous master's buffer instead of allocating a fresh
    (P,) vector.  Only pass it when the caller no longer needs ``prev``
    after the call (XLA copies defensively otherwise, losing the
    saving)."""
    m, p = clients.shape
    pad = (-p) % block
    if pad:
        clients = jnp.pad(clients, ((0, 0), (0, pad)))
        masks = jnp.pad(masks, ((0, 0), (0, pad)))
        prev_p = jnp.pad(prev, (0, pad))
    else:
        prev_p = prev
    n_blocks = (p + pad) // block

    out = pl.pallas_call(
        _kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((m, block), lambda i: (0, i)),
            pl.BlockSpec((m, block), lambda i: (0, i)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p + pad,), prev.dtype),
        input_output_aliases={3: 0} if donate_prev else {},
        interpret=interpret,
    )(clients, masks, weights, prev_p)
    return out[:p]
