"""Per-tensor symmetric int8 quantize/dequantize Pallas TPU kernels.

The wire codec's hot loop (``repro.comm.quantize``): map a float tensor
onto the 255-level symmetric grid ``{-127..127} * scale`` and back.
Both directions are pure memory-bound elementwise maps over a flat
(P,) vector — same blocking as ``fill_aggregate``: 1-D grid over
(8, 128)-aligned ``block``-sized tiles, the scalar scale broadcast to
every tile.  The scale itself (``max|x| / 127``) is a plain reduction
left to XLA; fusing it here would serialize the two passes the compiler
already overlaps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 64 * 128
QMAX = 127.0


def _quant_kernel(x_ref, s_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # (block,)
    scale = s_ref[...].astype(jnp.float32)      # (1,)
    q = jnp.clip(jnp.round(x / scale), -QMAX, QMAX)
    o_ref[...] = q.astype(jnp.int8)


def _dequant_kernel(q_ref, s_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)          # (block,)
    scale = s_ref[...].astype(jnp.float32)      # (1,)
    o_ref[...] = (q * scale).astype(o_ref.dtype)


def _blocked_1d(kernel, x, scale, out_dtype, block, interpret):
    """Run an elementwise (vector, scalar-scale) kernel over 1-D tiles."""
    p = x.shape[0]
    pad = (-p) % block
    if pad:
        x = jnp.pad(x, (0, pad))
    scale = jnp.reshape(scale, (1,)).astype(jnp.float32)
    out = pl.pallas_call(
        kernel,
        grid=((p + pad) // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p + pad,), out_dtype),
        interpret=interpret,
    )(x, scale)
    return out[:p]


def quantize_int8(x, scale, *, block=DEFAULT_BLOCK, interpret=True):
    """x: (P,) float; scale: scalar -> (P,) int8 on the symmetric grid."""
    return _blocked_1d(_quant_kernel, x, scale, jnp.int8, block, interpret)


def dequantize_int8(q, scale, *, dtype=jnp.float32, block=DEFAULT_BLOCK,
                    interpret=True):
    """q: (P,) int8; scale: scalar -> (P,) ``dtype`` (``q * scale``)."""
    return _blocked_1d(_dequant_kernel, q, scale, dtype, block, interpret)
