"""Mamba2 SSD chunk-scan Pallas TPU kernel.

grid = (batch, heads, chunks); the chunk axis is innermost/sequential and
the (P x N) recurrent state lives in VMEM scratch carried across chunks.
Per chunk (Q = chunk length):
  y_diag = ((C B^T) * L) X        -- intra-chunk, two (Q,Q)/(Q,P) MXU matmuls
  y_off  = (C S_in^T) * exp(cumA) -- contribution of the carried state
  S_out  = S_in * exp(A_q) + X^T (B * decay)
All math in fp32 inside VMEM; inputs are the pre-discretized tensors the
jnp oracle (models/ssm.ssd_chunked) produces.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, a_ref, b_ref, c_ref, y_ref, s_ref, state_scr, *,
            num_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[...].astype(jnp.float32)          # (Q, P) pre-scaled by dt
    a = a_ref[...].astype(jnp.float32)          # (Q,) log-decay
    bm = b_ref[...].astype(jnp.float32)         # (Q, N)
    cm = c_ref[...].astype(jnp.float32)         # (Q, N)
    q = x.shape[0]

    a_cum = jnp.cumsum(a)                       # (Q,)
    # L[i, j] = exp(a_cum[i] - a_cum[j]) for j <= i (segment decay)
    seg = a_cum[:, None] - a_cum[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (q, q), 1))
    l_mat = jnp.where(tri, jnp.exp(seg), 0.0)

    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    y_diag = jax.lax.dot_general(cb * l_mat, x, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    state_in = state_scr[...]                   # (P, N)
    y_off = jax.lax.dot_general(cm, state_in, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (Q, P)
    y_off = y_off * jnp.exp(a_cum)[:, None]
    y_ref[...] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: S = S * exp(a_total) + X^T (B * decay_to_end)
    decay = jnp.exp(a_cum[-1] - a_cum)          # (Q,)
    upd = jax.lax.dot_general(x, bm * decay[:, None],
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    state_scr[...] = state_in * jnp.exp(a_cum[-1]) + upd

    @pl.when(ci == num_chunks - 1)
    def _emit_state():
        s_ref[...] = state_scr[...]


def ssd_scan(x, a, b, c, initial_state=None, *, interpret=True):
    """x: (B, H, NC, Q, P); a: (B, H, NC, Q); b, c: (B, NC, Q, N).

    Returns (y: (B, H, NC, Q, P), final_state: (B, H, P, N)).
    ``initial_state`` must be None (zeros) — matching the oracle's default.
    """
    assert initial_state is None, "kernel assumes zero initial state"
    bsz, h, nc, q, p = x.shape
    n = b.shape[-1]

    kernel = functools.partial(_kernel, num_chunks=nc)
    y, s = pl.pallas_call(
        kernel,
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((None, None, None, q, p),
                         lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((None, None, None, q),
                         lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((None, None, q, n),
                         lambda bi, hi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((None, None, q, n),
                         lambda bi, hi, ci: (bi, ci, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, None, q, p),
                         lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((None, None, p, n),
                         lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, h, nc, q, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, a, b, c)
    return y, s
