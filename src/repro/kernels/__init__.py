"""Pallas TPU kernels for the perf-critical compute layers.

kernels: flash_attention (attention hot spot), ssd_scan (Mamba2 chunk
scan), fill_aggregate (paper Algorithm 3 server reduction), expert_gemm
(MoE grouped matmul).  ``ops`` holds the jit wrappers, ``ref`` the
pure-jnp oracles; per-kernel shape/dtype sweeps live in tests/.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
