"""Pure-jnp oracles for every Pallas kernel (the `assert_allclose` targets).

These intentionally re-state the math in the most straightforward form —
independent of the blocked/streamed kernel implementations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention(q, k, v, *, causal=True, window=0):
    """q: (B, S, H, D); k, v: (B, S, Kh, D) -> (B, S, H, D)."""
    b, s, h, d = q.shape
    kh = k.shape[2]
    if kh != h:
        k = jnp.repeat(k, h // kh, axis=2)
        v = jnp.repeat(v, h // kh, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(d)
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), dtype=bool)
    if causal:
        mask = mask & (ki <= qi)
    if window:
        mask = mask & (ki > qi - window)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_scan(xs, a, bm, cm):
    """Sequential (non-chunked) SSD recurrence — the ground truth.

    xs: (B, NC, Q, H, P) pre-scaled inputs; a: (B, NC, Q, H) log-decay;
    bm, cm: (B, NC, Q, N).  Returns (y (B, NC, Q, H, P), state (B,H,P,N)).
    """
    b, nc, q, h, p = xs.shape
    n = bm.shape[-1]
    x_f = xs.reshape(b, nc * q, h, p).astype(jnp.float32)
    a_f = a.reshape(b, nc * q, h).astype(jnp.float32)
    b_f = bm.reshape(b, nc * q, n).astype(jnp.float32)
    c_f = cm.reshape(b, nc * q, n).astype(jnp.float32)

    def step(state, t):
        x_t, a_t, b_t, c_t = t
        state = (state * jnp.exp(a_t)[:, :, None, None]
                 + jnp.einsum("bhp,bn->bhpn", x_t, b_t))
        y_t = jnp.einsum("bn,bhpn->bhp", c_t, state)
        return state, y_t

    s0 = jnp.zeros((b, h, p, n), jnp.float32)
    xs_t = (jnp.moveaxis(x_f, 1, 0), jnp.moveaxis(a_f, 1, 0),
            jnp.moveaxis(b_f, 1, 0), jnp.moveaxis(c_f, 1, 0))
    state, ys = jax.lax.scan(step, s0, xs_t)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc, q, h, p)
    return y, state


def fill_aggregate(clients, masks, weights, prev):
    cl = clients.astype(jnp.float32)
    mk = masks.astype(jnp.float32)
    filled = mk * cl + (1 - mk) * prev.astype(jnp.float32)[None, :]
    return jnp.einsum("m,mp->p", weights.astype(jnp.float32),
                      filled).astype(prev.dtype)


def expert_gemm(x, w):
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


def quantize_int8(x, scale):
    """x: (P,) float; scale: scalar -> (P,) int8 on the symmetric
    255-level grid (round-to-nearest-even, clipped to [-127, 127])."""
    q = jnp.clip(jnp.round(x.astype(jnp.float32)
                           / jnp.float32(scale)), -127.0, 127.0)
    return q.astype(jnp.int8)


def dequantize_int8(q, scale, dtype=jnp.float32):
    """q: (P,) int8; scale: scalar -> (P,) ``dtype`` (``q * scale``)."""
    return (q.astype(jnp.float32) * jnp.float32(scale)).astype(dtype)
