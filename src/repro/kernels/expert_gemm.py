"""Grouped (per-expert) GEMM Pallas TPU kernel.

Computes out[e] = x[e] @ w[e] for E experts: the compute core of the MoE
layer after dispatch.  grid = (E, C/bc, F/bf, D/bd) with the contraction
axis innermost/sequential and a (bc, bf) fp32 accumulator in VMEM scratch —
the canonical MXU matmul tiling, one expert per outer grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_C = 128
BLOCK_F = 128
BLOCK_D = 256


def _kernel(x_ref, w_ref, o_ref, acc_scr, *, num_d_blocks: int):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(di == num_d_blocks - 1)
    def _emit():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def expert_gemm(x, w, *, block_c=BLOCK_C, block_f=BLOCK_F, block_d=BLOCK_D,
                interpret=True):
    """x: (E, C, D); w: (E, D, F) -> (E, C, F)."""
    e, c, d = x.shape
    _, _, f = w.shape
    block_c = min(block_c, c)
    block_f = min(block_f, f)
    block_d = min(block_d, d)
    assert c % block_c == 0 and f % block_f == 0 and d % block_d == 0, \
        (c, f, d, block_c, block_f, block_d)
    nd = d // block_d

    kernel = functools.partial(_kernel, num_d_blocks=nd)
    return pl.pallas_call(
        kernel,
        grid=(e, c // block_c, f // block_f, nd),
        in_specs=[
            pl.BlockSpec((None, block_c, block_d),
                         lambda ei, ci, fi, di: (ei, ci, di)),
            pl.BlockSpec((None, block_d, block_f),
                         lambda ei, ci, fi, di: (ei, di, fi)),
        ],
        out_specs=pl.BlockSpec((None, block_c, block_f),
                               lambda ei, ci, fi, di: (ei, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(x, w)
