"""jit'd public wrappers around the Pallas kernels.

``INTERPRET`` defaults to True (this container is CPU-only; interpret mode
executes kernel bodies in Python for validation).  On real TPUs set
``repro.kernels.ops.INTERPRET = False`` once at startup.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import expert_gemm as _eg
from repro.kernels import fill_aggregate as _fa
from repro.kernels import flash_attention as _flash
from repro.kernels import quantize as _q
from repro.kernels import ssd_scan as _ssd

INTERPRET = True


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, S, H, D); k, v: (B, S, Kh, D) -> (B, S, H, D).

    GQA K/V are repeated to the full head count here (broadcast; stays
    sharded — see models/attention.py) and batch*heads fold into the
    kernel's leading grid axis.
    """
    b, s, h, d = q.shape
    kh = k.shape[2]
    if kh != h:
        k = jnp.repeat(k, h // kh, axis=2)
        v = jnp.repeat(v, h // kh, axis=2)
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    out = _flash.flash_attention(fold(q), fold(k), fold(v), causal=causal,
                                 window=window, interpret=INTERPRET)
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)


@jax.jit
def ssd_scan(xs, a, bm, cm, initial_state=None):
    """Oracle-layout adapter: xs (B, NC, Q, H, P); a (B, NC, Q, H);
    bm, cm (B, NC, Q, N) -> (y (B, NC, Q, H, P), state (B, H, P, N))."""
    x_k = jnp.moveaxis(xs, 3, 1)                 # (B, H, NC, Q, P)
    a_k = jnp.moveaxis(a, 3, 1)                  # (B, H, NC, Q)
    y, s = _ssd.ssd_scan(x_k, a_k, bm, cm, initial_state,
                         interpret=INTERPRET)
    return jnp.moveaxis(y, 1, 3), s


@jax.jit
def _fill_aggregate_jit(clients, masks, weights, prev):
    return _fa.fill_aggregate(clients, masks, weights, prev,
                              interpret=INTERPRET)


@functools.partial(jax.jit, donate_argnums=(3,))
def _fill_aggregate_donate_jit(clients, masks, weights, prev):
    return _fa.fill_aggregate(clients, masks, weights, prev,
                              interpret=INTERPRET, donate_prev=True)


def fill_aggregate(clients, masks, weights, prev, donate_prev=False):
    """clients, masks: (m, P); weights: (m,); prev: (P,) -> (P,).

    ``donate_prev`` donates the ``prev`` buffer at the jit boundary AND
    aliases the kernel's (block-padded) prev into its output
    (``input_output_aliases``), so the master update writes over the
    previous master's vector instead of allocating a fresh one.  Pass it
    only when ``prev`` is dead after the call (the last-chunk master
    update).  On CPU — where XLA cannot reuse donated buffers and warns
    per dispatch — the plain path is used regardless."""
    if donate_prev and jax.default_backend() != "cpu":
        return _fill_aggregate_donate_jit(clients, masks, weights, prev)
    return _fill_aggregate_jit(clients, masks, weights, prev)


@jax.jit
def expert_gemm(x, w):
    """x: (E, C, D); w: (E, D, F) -> (E, C, F)."""
    return _eg.expert_gemm(x, w, interpret=INTERPRET)


@jax.jit
def quantize_int8(x, scale):
    """x: (P,) float; scale: scalar -> (P,) int8 (symmetric grid)."""
    return _q.quantize_int8(x, scale, interpret=INTERPRET)


@jax.jit
def dequantize_int8(q, scale):
    """q: (P,) int8; scale: scalar -> (P,) float32 (``q * scale``)."""
    return _q.dequantize_int8(q, scale, interpret=INTERPRET)


@jax.jit
def expert_ffn(experts, x):
    """SwiGLU expert FFN on dispatched slots via the grouped-GEMM kernel.
    x: (E, C, d) -> (E, C, d)."""
    h = expert_gemm(x, experts["wi"])
    g = expert_gemm(x, experts["wg"])
    return expert_gemm(jax.nn.silu(g) * h, experts["wo"])
