# NOTE: dryrun is intentionally NOT imported here — importing it sets
# XLA_FLAGS to 512 placeholder devices, which only the dry-run may do.
from repro.launch.mesh import make_host_mesh, make_production_mesh

__all__ = ["make_host_mesh", "make_production_mesh"]
