"""ShapeDtypeStruct stand-ins for every model input — the dry-run lowers
against these (weak-type-correct, shardable, zero allocation).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import transformer as tr

SDS = jax.ShapeDtypeStruct


def effective_window(cfg: ModelConfig, shape: InputShape) -> int:
    """long_500k forces the sliding-window attention variant for every
    attention-bearing arch (DESIGN.md Section 4); other shapes use full
    attention."""
    return cfg.sliding_window if shape.sliding else 0


def cache_len(cfg: ModelConfig, shape: InputShape) -> int:
    w = effective_window(cfg, shape)
    return min(shape.seq_len, w) if w else shape.seq_len


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Step-function inputs for (arch x shape), ShapeDtypeStruct only."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def prefix_spec():
        if cfg.family in ("vlm", "audio"):
            return SDS((b, cfg.num_prefix, cfg.d_model), cfg.jdtype)
        return None

    if shape.kind == "train":
        out = {"tokens": SDS((b, s), i32), "labels": SDS((b, s), i32)}
    elif shape.kind == "prefill":
        out = {"tokens": SDS((b, s), i32)}
    else:  # decode: ONE new token against a seq_len-deep cache
        out = {"token": SDS((b, 1), i32)}
    p = prefix_spec()
    if p is not None and shape.kind != "decode":
        out["prefix"] = p
    return out


def abstract_params(cfg: ModelConfig) -> Any:
    return jax.eval_shape(
        lambda: tr.init_params(jax.random.PRNGKey(0), cfg))


def abstract_cache(cfg: ModelConfig, shape: InputShape) -> Any:
    b = shape.global_batch
    cl = cache_len(cfg, shape)
    enc_len = cfg.num_prefix if cfg.family == "audio" else 0
    return jax.eval_shape(
        lambda: tr.init_cache(abstract_params(cfg), cfg, b, cl,
                              enc_len=enc_len))
