"""Serving steps: prefill (full-sequence forward producing first logits) and
single-token decode against the KV/SSM cache — these are what the
``decode_32k`` / ``long_500k`` shapes lower — plus a batched greedy
generation driver for the CPU example.
"""
from __future__ import annotations

import argparse
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig, get_config
from repro.models import transformer as tr


def make_prefill_step(cfg: ModelConfig, *, window: int = 0,
                      backend: str = "xla", unroll: bool = False) -> Callable:
    def prefill_step(params, batch):
        logits, _, _ = tr.forward(params, cfg, batch["tokens"],
                                  prefix=batch.get("prefix"), window=window,
                                  backend=backend, remat=False,
                                  unroll=unroll)
        return logits[:, -1:, :]
    return prefill_step


def make_decode_step(cfg: ModelConfig, *, window: int = 0,
                     unroll: bool = False) -> Callable:
    def decode_step(params, cache, batch):
        return tr.decode_step(params, cfg, batch["token"], cache,
                              window=window, unroll=unroll)
    return decode_step


def greedy_generate(params, cfg: ModelConfig, prompt: jax.Array,
                    steps: int, cache_len: int = 0, window: int = 0,
                    prefix: Optional[jax.Array] = None) -> jax.Array:
    """Batched greedy decoding for the CPU serving example."""
    b, s = prompt.shape
    cl = cache_len or (s + steps)
    enc_out = None
    if cfg.family == "audio":
        enc_out = tr.encode(params, cfg, prefix)
    # replay all but the last prompt token; the last one is decoded so its
    # logits pick the first generated token
    cache = tr.prefill_cache(params, cfg, prompt[:, :-1], window=window,
                             cache_len=cl, enc_out=enc_out)
    step = jax.jit(make_decode_step(cfg, window=window))
    last = prompt[:, -1:]
    out = [prompt]
    for _ in range(steps):
        logits, cache = step(params, cache, {"token": last})
        last = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        out.append(last)
    return jnp.concatenate(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser(description="CPU-scale serving driver")
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    rng = jax.random.PRNGKey(0)
    params = tr.init_params(rng, cfg)
    prompt = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size, dtype=jnp.int32)
    prefix = None
    if cfg.family in ("vlm", "audio"):
        prefix = jnp.zeros((args.batch, cfg.num_prefix, cfg.d_model),
                           jnp.float32)
    toks = greedy_generate(params, cfg, prompt, args.steps, prefix=prefix)
    print(f"{cfg.name}: generated {toks.shape} tokens")
    print(toks[0])


if __name__ == "__main__":
    main()
