"""Sharding policy: megatron tensor-parallel + FSDP hybrid.

Rules are path-based over the parameter pytree; every rule degrades to
replication when a dimension is not divisible by the mesh axis (e.g. odd
vocab sizes like whisper's 51866 cannot shard over model=16, so the
embedding flips to sharding d_model instead).

Layout summary (2D logical mesh: data ~ fsdp axis, model ~ tensor axis):
  embed (V, d)           -> (model, fsdp)  [or (fsdp, model) if V % model]
  attn wq/wk/wv (d, Hh)  -> (fsdp, model);  wo (Hh, d) -> (model, fsdp)
  mlp wi/wg (d, f)       -> (fsdp, model);  wo (f, d)  -> (model, fsdp)
  moe experts (E, d, f)  -> (model=expert-parallel, fsdp, -)
  ssm in_proj (d, x)     -> (fsdp, model);  out_proj   -> (model, fsdp)
  norms / scalars        -> replicated
Stacked (L, ...) / supernet (L, B, ...) leading axes are never sharded.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes, mesh_axis_size

Params = Any


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    return mesh_axis_size(mesh, tuple(axis) if isinstance(axis, list)
                          else axis)


def _fits(mesh: Mesh, dim: int, axis) -> bool:
    return dim % _axis_size(mesh, axis) == 0


def _guarded(mesh: Mesh, shape: Sequence[int], spec: Sequence) -> P:
    """Replicate any dim that does not divide its assigned axis."""
    out = []
    for dim, axis in zip(shape, spec):
        out.append(axis if (axis is not None and _fits(mesh, dim, axis))
                   else None)
    return P(*out)


def param_spec(mesh: Mesh, path: str, shape: Sequence[int]) -> P:
    """PartitionSpec for one parameter leaf, identified by its '/' path."""
    fsdp = data_axes(mesh)          # ("pod","data") or ("data",)
    ndim = len(shape)

    def base(spec2d):
        """Right-align a trailing-dims spec; leading (L, branch) dims
        replicate."""
        pad = [None] * (ndim - len(spec2d))
        return _guarded(mesh, shape, pad + list(spec2d))

    name = path.split("/")[-1]
    if "embed" in path and name == "table":
        if _fits(mesh, shape[0], "model"):
            return base(["model", fsdp])
        # odd vocab (whisper 51866, granite 49155, ...): sharding d_model
        # over 'model' instead trips an SPMD-partitioner bug in the gather's
        # jvp inside the microbatch loop (invalid dynamic-slice); these
        # tables are all < 300 MB — replicate them.
        return base([None, fsdp])
    if "experts" in path:
        if name in ("wi", "wg"):
            return base(["model", fsdp, None])
        if name == "wo":
            return base(["model", None, fsdp])
    if "router" in path:
        return base([None, None])
    if name == "w":
        parent = path.split("/")[-2]
        if parent in ("wq", "wk", "wv", "wi", "wg", "in_proj", "proj"):
            return base([fsdp, "model"])
        if parent in ("wo", "out_proj"):
            return base(["model", fsdp])
        if parent.startswith(("z_proj", "x_proj", "b_proj", "c_proj",
                              "dt_proj")):
            return base([fsdp, "model"])
        if parent.startswith("conv"):
            return base([None, "model"])
        if parent == "fc":
            return base([None, None])
    if name == "b":
        parent = path.split("/")[-2]
        if parent in ("wq", "wk", "wv", "wi", "wg", "in_proj") or \
                parent.startswith(("conv", "z_proj", "x_proj", "b_proj",
                                   "c_proj", "dt_proj")):
            return base(["model"])
        return base([None])
    # conv_w, A_log, dt_bias, D, norms, scalars -> replicated
    return P(*([None] * ndim))


def _path_str(path) -> str:
    import re
    return "/".join(re.sub(r"[\[\]'.]", "", str(p)) for p in path)


def param_specs(mesh: Mesh, params: Params) -> Params:
    """Tree of PartitionSpecs matching ``params`` (works on
    ShapeDtypeStructs — no allocation needed)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [param_spec(mesh, _path_str(p), leaf.shape) for p, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(mesh: Mesh, params: Params) -> Params:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(mesh, params))


# ---------------------------------------------------------------------------
# Activation / batch / cache specs
# ---------------------------------------------------------------------------

def batch_spec(mesh: Mesh, batch_size: int, ndim: int) -> P:
    """Shard the leading batch dim over the data axes when divisible.

    Also used by ``repro.engine.mesh_backend`` to place the engine's
    population-stacked tensors (leading axis = padded population) on the
    mesh."""
    fsdp = data_axes(mesh)
    lead = fsdp if batch_size % _axis_size(mesh, fsdp) == 0 else None
    return P(*([lead] + [None] * (ndim - 1)))


def cache_spec(mesh: Mesh, path: str, shape: Sequence[int],
               batch: int) -> P:
    """KV/SSM cache sharding: batch over data when divisible, the cache
    sequence dim (kv ring) over model; SSM state heads over model."""
    fsdp = data_axes(mesh)
    name = path.split("/")[-1]
    bdim = fsdp if batch % _axis_size(mesh, fsdp) == 0 else None
    ndim = len(shape)
    if name in ("k", "v", "cross_k", "cross_v"):
        # (..., B, C, Kh, hd).  Prefer sharding head_dim over 'model': the
        # ring-buffer write (dynamic-update-slice at a traced slot) is then
        # shard-local.  Sharding the cache-length dim instead makes GSPMD
        # reshard the whole cache around every update (measured ~26 GB of
        # collectives per decoded token for granite decode_32k).
        if _fits(mesh, shape[-1], "model"):
            spec = [None] * (ndim - 4) + [bdim, None, None, "model"]
        else:
            spec = [None] * (ndim - 4) + [bdim, "model", None, None]
        return _guarded(mesh, shape, spec)
    if name == "state":
        # (..., B, H, P, N)
        spec = [None] * (ndim - 4) + [bdim, "model", None, None]
        return _guarded(mesh, shape, spec)
    if name.startswith("conv"):
        # (..., B, K-1, C)
        spec = [None] * (ndim - 3) + [bdim, None, "model"]
        return _guarded(mesh, shape, spec)
    return P(*([None] * ndim))


def cache_specs(mesh: Mesh, cache: Params, batch: int) -> Params:
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    specs = [cache_spec(mesh, _path_str(p), leaf.shape, batch)
             for p, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)
