import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape) this lowers + compiles the real step
function (train_step / prefill_step / decode_step) against ShapeDtypeStruct
inputs on the production mesh (16x16 single-pod, 2x16x16 multi-pod), prints
memory_analysis() (proves it fits) and cost_analysis() (FLOPs/bytes for the
roofline), parses collective bytes from the optimized HLO, and writes one
JSON record per combination under benchmarks/results/.

Measurement methodology (see EXPERIMENTS.md §Dry-run):
  * the FULL-depth model compiles with scan-over-layers (the production
    form) — this is the pass/fail gate and the memory_analysis source;
  * XLA's HloCostAnalysis counts while-loop bodies ONCE (not x trip count),
    so roofline FLOPs/bytes/collective-bytes come from a pair of shallow
    UNROLLED compiles (depths L1 < L2 << L): per-layer slope
    (f(L2)-f(L1))/(L2-L1) + intercept, extrapolated to the full depth.
    Layers are structurally identical, so the extrapolation is exact up to
    boundary fusion effects.

The two lines above MUST stay the first statements in this module: jax
locks the device count at first init, and only the dry-run may see 512
placeholder devices.
"""
import argparse
import json
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_ALIASES, SHAPES, get_config, get_shape
from repro.core import flops as flops_mod
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.serve import make_decode_step, make_prefill_step
from repro.launch.sharding import batch_spec, cache_specs, param_specs
from repro.launch.specs import (
    abstract_cache, abstract_params, cache_len, effective_window, input_specs,
)
from repro.launch.train import init_opt, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results")

# train_4k microbatching so the big configs fit 16 GB/chip (activation
# memory scales 1/microbatch; see EXPERIMENTS.md Perf log)
DEFAULT_MICROBATCH = {
    "deepseek-67b": 8,
    "llama4-scout-17b-a16e": 4,
    "whisper-large-v3": 4,
    "chatglm3-6b": 2,
    "starcoder2-3b": 2,
    "zamba2-2.7b": 2,
    "mamba2-780m": 4,
    "granite-moe-1b-a400m": 2,
}


def _depth_pair(cfg) -> Tuple[int, int]:
    if cfg.family == "hybrid":
        k = cfg.attn_every
        return (k, 2 * k)          # keep the shared-attn period intact
    return (4, 8)


def _with_depth(cfg, depth: int):
    kw = {"num_layers": depth}
    if cfg.encoder_layers:
        kw["encoder_layers"] = depth
    return cfg.replace(**kw)


def _compile_one(cfg, shape, mesh, *, unroll: bool, backend: str,
                 remat: bool, fused_ce: bool, supernet: bool,
                 microbatch: int = 1):
    """Lower + compile one step function; returns (compiled, seconds)."""
    window = effective_window(cfg, shape)
    specs = input_specs(cfg, shape)
    params = abstract_params(cfg)
    p_specs = param_specs(mesh, params)
    in_batch_specs = {k: batch_spec(mesh, shape.global_batch, len(v.shape))
                      for k, v in specs.items()}
    if supernet and shape.kind == "train":
        specs["choice_key"] = jax.ShapeDtypeStruct((cfg.num_layers,),
                                                   jnp.int32)
        in_batch_specs["choice_key"] = P()

    def sh(tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                            is_leaf=lambda x: isinstance(x, P))

    from repro.launch import policy
    policy.set_mesh(mesh)
    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            opt = jax.eval_shape(lambda p: init_opt(p), params)
            o_specs = param_specs(mesh, opt)
            step = make_train_step(cfg, window=window, backend=backend,
                                   remat=remat, fused_ce=fused_ce,
                                   unroll=unroll, microbatch=microbatch)
            jf = jax.jit(step,
                         in_shardings=sh((p_specs, o_specs, in_batch_specs)),
                         out_shardings=sh((p_specs, o_specs, P())))
            lowered = jf.lower(params, opt, specs)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, window=window, backend=backend,
                                     unroll=unroll)
            jf = jax.jit(step,
                         in_shardings=sh((p_specs, in_batch_specs)),
                         out_shardings=sh(batch_spec(mesh,
                                                     shape.global_batch, 3)))
            lowered = jf.lower(params, specs)
        else:  # decode
            cache = abstract_cache(cfg, shape)
            c_specs = cache_specs(mesh, cache, shape.global_batch)
            step = make_decode_step(cfg, window=window, unroll=unroll)
            # donate the cache: ring updates alias in place (production
            # serving semantics; also removes full-cache copy traffic)
            jf = jax.jit(step,
                         in_shardings=sh((p_specs, c_specs, in_batch_specs)),
                         out_shardings=sh((batch_spec(mesh,
                                                      shape.global_batch, 3),
                                           c_specs)),
                         donate_argnums=(1,))
            lowered = jf.lower(params, cache, specs)
        compiled = lowered.compile()
    policy.set_mesh(None)
    return compiled, time.time() - t0


def _costs(compiled) -> Dict[str, Any]:
    cost = compiled.cost_analysis()
    coll = rl.parse_collectives(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll["total"], "coll_ops": coll["ops"],
            "coll_by_kind": {k: coll[k] for k in rl.COLLECTIVE_KINDS}}


def dry_run(arch: str, shape_name: str, *, multi_pod: bool = False,
            supernet: bool = False, backend: str = "xla",
            remat: bool = True, fused_ce: bool = True,
            roofline: bool = True, microbatch: int = 0,
            verbose: bool = True,
            extra_tag: str = "") -> Dict[str, Any]:
    cfg = get_config(arch)
    if supernet:
        cfg = cfg.replace(supernet=True)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    if microbatch <= 0:
        microbatch = DEFAULT_MICROBATCH.get(cfg.name, 1) \
            if shape.kind == "train" else 1
    kw = dict(backend=backend, remat=remat, fused_ce=fused_ce,
              supernet=supernet, microbatch=microbatch)

    # 1) full-depth, scan-over-layers: the compile gate + memory analysis
    compiled, compile_s = _compile_one(cfg, shape, mesh, unroll=False, **kw)
    mem = compiled.memory_analysis()

    rec: Dict[str, Any] = {
        "arch": cfg.name, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "kind": shape.kind, "window": effective_window(cfg, shape),
        "supernet": supernet, "backend": backend, "remat": remat,
        "fused_ce": fused_ce, "microbatch": microbatch, "tag": extra_tag,
        "compile_s": round(compile_s, 1),
    }
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                rec[attr] = int(v)

    # 2) roofline terms: shallow unrolled depth pair -> per-layer slope.
    # microbatch forced to 1 here: the microbatch accumulator is a while
    # loop whose body HloCostAnalysis counts once, hiding a microbatch-
    # factor of the arithmetic (the gate compile above keeps the real
    # microbatching for the memory analysis).
    if roofline:
        rkw = dict(kw, microbatch=1)
        l1, l2 = _depth_pair(cfg)
        c1, _ = _compile_one(_with_depth(cfg, l1), shape, mesh,
                             unroll=True, **rkw)
        c2, _ = _compile_one(_with_depth(cfg, l2), shape, mesh,
                             unroll=True, **rkw)
        f1, f2 = _costs(c1), _costs(c2)
        L = cfg.num_layers

        def extrap(v1, v2):
            slope = (v2 - v1) / (l2 - l1)
            return max(v2 + slope * (L - l2), 0.0)

        flops_dev = extrap(f1["flops"], f2["flops"])
        bytes_dev = extrap(f1["bytes"], f2["bytes"])
        coll_dev = extrap(f1["coll"], f2["coll"])
        terms = rl.roofline_terms(flops_dev, bytes_dev, coll_dev)
        coll_kind = {k: extrap(f1["coll_by_kind"][k], f2["coll_by_kind"][k])
                     for k in rl.COLLECTIVE_KINDS}

        if shape.kind == "train":
            model_flops = flops_mod.train_flops(
                cfg, shape.global_batch * shape.seq_len)
        elif shape.kind == "prefill":
            model_flops = flops_mod.train_flops(
                cfg, shape.global_batch * shape.seq_len) / 3.0  # fwd only
        else:
            model_flops = flops_mod.decode_flops(cfg, shape.global_batch)

        rec.update({
            "flops_per_dev": flops_dev, "bytes_per_dev": bytes_dev,
            "collective_bytes_per_dev": coll_dev,
            "collectives": coll_kind,
            "model_flops_global": model_flops,
            "useful_flops_ratio": (model_flops / (flops_dev * chips)
                                   if flops_dev else 0.0),
            "depth_pair": [l1, l2],
            **terms,
        })

    if verbose:
        print(f"== {cfg.name} x {shape_name} on {rec['mesh']} "
              f"({chips} chips){' [supernet]' if supernet else ''}"
              f"{' [' + extra_tag + ']' if extra_tag else ''}")
        print(f"   full-depth compile {compile_s:.1f}s | "
              f"args {rec.get('argument_size_in_bytes', 0)/1e9:.2f}GB "
              f"temp {rec.get('temp_size_in_bytes', 0)/1e9:.2f}GB per dev")
        if roofline:
            print(f"   per-dev flops {flops_dev:.3e} bytes {bytes_dev:.3e} "
                  f"coll {coll_dev:.3e}")
            print(f"   roofline: compute {rec['compute_s']*1e3:.3f}ms "
                  f"memory {rec['memory_s']*1e3:.3f}ms "
                  f"collective {rec['collective_s']*1e3:.3f}ms "
                  f"-> {rec['dominant']}-bound | "
                  f"MODEL/HLO {rec['useful_flops_ratio']:.3f}")
    return rec


def save_record(rec: Dict[str, Any]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    tag = f"_{rec['tag']}" if rec.get("tag") else ""
    sup = "_supernet" if rec.get("supernet") else ""
    name = (f"dryrun_{rec['arch'].replace('.', 'p')}_{rec['shape']}_"
            f"{rec['mesh'].replace('x', '-')}{sup}{tag}.json")
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--supernet", action="store_true")
    ap.add_argument("--backend", default="xla", choices=["xla", "pallas", "chunked"])
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-fused-ce", action="store_true")
    ap.add_argument("--no-roofline", action="store_true",
                    help="compile gate only (skip the unrolled depth pair)")
    ap.add_argument("--microbatch", type=int, default=0,
                    help="0 = per-arch default")
    ap.add_argument("--tag", default="")
    ap.add_argument("--save", action="store_true")
    args = ap.parse_args()

    archs = ([a for a in ARCH_ALIASES if a != "cifar-supernet"]
             if args.arch == "all" else [args.arch])
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    rec = dry_run(arch, shape, multi_pod=mp,
                                  supernet=args.supernet,
                                  backend=args.backend,
                                  remat=not args.no_remat,
                                  fused_ce=not args.no_fused_ce,
                                  roofline=not args.no_roofline,
                                  microbatch=args.microbatch,
                                  extra_tag=args.tag)
                    if args.save:
                        save_record(rec)
                except Exception as e:  # noqa: BLE001 — report, keep going
                    failures.append((arch, shape, mp, repr(e)[:400]))
                    print(f"!! FAIL {arch} x {shape} multi_pod={mp}: "
                          f"{repr(e)[:400]}")
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures")
    print("ALL DRY-RUNS PASSED")


if __name__ == "__main__":
    main()
