"""Distribution policy context.

The model code is mesh-agnostic; the launcher (dry-run / trainer / server)
registers the active mesh here, and layers that have an explicitly-
distributed implementation (shard_map expert-parallel MoE) pick it up.
When no mesh is registered (CPU tests, single host) every layer uses its
pure-GSPMD formulation.
"""
from __future__ import annotations

from typing import Optional

from jax.sharding import Mesh

_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _MESH


def data_axis_size(mesh: Mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n
