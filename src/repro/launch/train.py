"""Training step + CLI driver.

``make_train_step`` builds the jit-able (params, opt, batch, step) ->
(params, opt, loss) function used both by the multi-pod dry-run (lower +
compile against ShapeDtypeStructs) and by the CPU example drivers (real
steps on the host mesh).  The optimizer is the paper's SGD + momentum by
default; ``optimizer='adamw'`` selects AdamW for LM pretraining runs.
"""
from __future__ import annotations

import argparse
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig, get_config, get_shape
from repro.models import transformer as tr
from repro.models.layers import cross_entropy
from repro.optim import adamw_init, adamw_update, sgd_init, sgd_update

AUX_WEIGHT = 0.01


def make_loss_fn(cfg: ModelConfig, *, window: int = 0, backend: str = "xla",
                 remat: bool = True, fused_ce: bool = True,
                 unroll: bool = False) -> Callable:
    from repro.models.layers import fused_cross_entropy

    def loss_fn(params, batch):
        out, aux, _ = tr.forward(
            params, cfg, batch["tokens"], prefix=batch.get("prefix"),
            choice_key=batch.get("choice_key"), window=window,
            backend=backend, remat=remat, return_hidden=fused_ce,
            unroll=unroll)
        if fused_ce:
            loss = fused_cross_entropy(out, params["embed"]["table"],
                                       batch["labels"])
        else:
            loss = cross_entropy(out, batch["labels"])
        return loss + AUX_WEIGHT * aux
    return loss_fn


def init_opt(params, optimizer: str = "sgd"):
    return adamw_init(params) if optimizer == "adamw" else sgd_init(params)


def make_train_step(cfg: ModelConfig, *, optimizer: str = "sgd",
                    lr: float = 0.1, momentum: float = 0.5,
                    window: int = 0, backend: str = "xla",
                    remat: bool = True, fused_ce: bool = True,
                    unroll: bool = False, microbatch: int = 1) -> Callable:
    """``microbatch`` > 1 splits the global batch into that many
    sequentially-accumulated microbatches — activation memory (remat
    carries, attention workspaces) scales down by the same factor while
    arithmetic is unchanged; the standard fit-67B-on-16GB-chips lever."""
    loss_fn = make_loss_fn(cfg, window=window, backend=backend, remat=remat,
                           fused_ce=fused_ce, unroll=unroll)

    def grads_of(params, batch):
        if microbatch <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def split(x):
            return x.reshape((microbatch, x.shape[0] // microbatch)
                             + x.shape[1:])

        micro = {k: split(v) for k, v in batch.items()
                 if k != "choice_key"}
        if "choice_key" in batch:
            micro = {**micro,
                     "choice_key": jnp.broadcast_to(
                         batch["choice_key"],
                         (microbatch,) + batch["choice_key"].shape)}

        def one(carry, mb):
            acc, tot = carry
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), acc, g)
            return (acc, tot + loss), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (acc, tot), _ = jax.lax.scan(one, (zeros, jnp.float32(0.0)), micro)
        scale = 1.0 / microbatch
        grads = jax.tree.map(lambda g: (g * scale), acc)
        return tot * scale, grads

    def train_step(params, opt, batch):
        loss, grads = grads_of(params, batch)
        if optimizer == "adamw":
            params, opt = adamw_update(params, grads, opt, lr)
        else:
            params, opt = sgd_update(params, grads, opt, lr, momentum)
        return params, opt, loss

    return train_step


def main() -> None:
    ap = argparse.ArgumentParser(description="CPU-scale training driver")
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw")
    args = ap.parse_args()

    import numpy as np
    from repro.data import make_lm_stream

    cfg = get_config(args.arch, smoke=True)
    rng = jax.random.PRNGKey(0)
    params = tr.init_params(rng, cfg)
    opt = init_opt(params, args.optimizer)
    step_fn = jax.jit(make_train_step(cfg, optimizer=args.optimizer,
                                      lr=args.lr, remat=False))
    x, y = make_lm_stream(0, args.steps * args.batch, args.seq,
                          cfg.vocab_size)
    for i in range(args.steps):
        batch = {"tokens": x[i * args.batch:(i + 1) * args.batch],
                 "labels": y[i * args.batch:(i + 1) * args.batch]}
        if cfg.family in ("vlm", "audio"):
            batch["prefix"] = np.zeros(
                (args.batch, cfg.num_prefix, cfg.d_model), np.float32)
        params, opt, loss = step_fn(params, opt, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(loss):.4f}")


if __name__ == "__main__":
    main()
