"""Production mesh construction (TPU v5e fleet).

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run driver sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, while tests and benches must keep seeing the single real device.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """(n_devices, 1) mesh over the local device(s) — the CPU examples and
    the engine's ``MeshBackend`` use it so the same pjit/shard_map code
    paths run everywhere.  Under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` this yields an
    N-way ``data`` axis on plain CPU hosts (the multi-device CI recipe)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def data_axes(mesh: Mesh):
    """Axes the global batch — or the engine's population axis — is
    sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def fsdp_axes(mesh: Mesh):
    """Axes the parameter 'replicated' dim is FSDP-sharded over."""
    return data_axes(mesh)


def mesh_axis_size(mesh: Mesh, axes) -> int:
    """Total device count along ``axes`` (one name or a tuple of names)."""
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
