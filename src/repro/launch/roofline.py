"""Roofline analysis from the compiled dry-run artifact (no real hardware).

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.  ``cost_analysis`` FLOPs/bytes from the post-SPMD module
are PER-DEVICE quantities (verified in tests/test_dryrun.py), so the
roofline terms divide only collective bytes by the chip count where the
parse is of per-device programs too; see ``roofline_terms``.

collective_bytes is not in cost_analysis: we parse the optimized HLO and
sum operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute.
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64"
                       r"|f64|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def parse_collectives(hlo_text: str) -> Dict[str, float]:
    """Sum operand bytes per collective kind from optimized HLO text."""
    out: Dict[str, float] = {k: 0.0 for k in COLLECTIVE_KINDS}
    count: Dict[str, int] = {k: 0 for k in COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.-]+\s*=\s*[^=]*?\b"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)(-start|-done)?\(", line)
        if not m:
            continue
        kind, phase = m.group(1), m.group(2)
        if phase == "-done":
            continue  # counted at -start
        # Optimized HLO does not always annotate operand types, so take the
        # larger of (result-side, operand-side) shape sums as the per-device
        # data volume of the op.  metadata/replica_groups never match the
        # dtype[dims] pattern.
        lhs_b = sum(_shape_bytes(dt, dims)
                    for dt, dims in _SHAPE_RE.findall(line[: m.end()]))
        rhs_b = sum(_shape_bytes(dt, dims)
                    for dt, dims in _SHAPE_RE.findall(line[m.end():]))
        out[kind] += max(lhs_b, rhs_b)
        count[kind] += 1
    out["total"] = sum(out[k] for k in COLLECTIVE_KINDS)
    out["ops"] = float(sum(count.values()))
    return out


def roofline_terms(per_device_flops: float, per_device_bytes: float,
                   per_device_collective_bytes: float,
                   ici_links: int = 4) -> Dict[str, float]:
    """Three roofline terms in seconds (per step, per chip).

    All inputs are per-device quantities (post-SPMD module).  ``ici_links``
    is the number of ICI links a v5e chip drives concurrently on a 2D torus
    (4: +-x, +-y).
    """
    compute = per_device_flops / PEAK_FLOPS
    memory = per_device_bytes / HBM_BW
    collective = per_device_collective_bytes / (ICI_BW * ici_links)
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda kv: kv[1])[0]
    return {"compute_s": compute, "memory_s": memory,
            "collective_s": collective, "dominant": dominant}
