"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m",
        family="moe",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=8,
        d_ff=512,
        vocab_size=49155,
        num_experts=32,
        top_k=8,
        moe_d_ff=512,
        rope_style="1d",
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, d_ff=64,
        moe_d_ff=64, vocab_size=512, num_experts=4, top_k=2, dtype="float32",
    )
