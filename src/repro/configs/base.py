"""Config system: architecture configs and input-shape registry.

Every assigned architecture gets one module in this package exposing
``config()`` (the exact published spec, cited) and ``smoke_config()``
(a reduced variant of the same family: <=2 layers, d_model<=512,
<=4 experts) used by CPU smoke tests.  The full configs are exercised
only through the multi-pod dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters for every model family in the zoo."""

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio | cnn
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    # --- attention ---
    head_dim: int = 0               # 0 -> d_model // num_heads
    rope_style: str = "1d"          # 1d | 2d (chatglm) | none
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 8192      # used when a shape requests the sliding variant
    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0               # expert hidden size (0 -> d_ff)
    shared_expert: bool = False     # llama4-style always-on shared expert
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    # --- hybrid (zamba2) ---
    attn_every: int = 0             # shared attention block applied every k layers
    # --- encoder-decoder / multimodal frontend stubs ---
    encoder_layers: int = 0
    num_prefix: int = 0             # stub frontend tokens (audio frames / image patches)
    # --- supernet (the paper's technique) ---
    supernet: bool = False
    num_branches: int = 4
    # --- numerics ---
    dtype: str = "bfloat16"
    # --- citation ---
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_layers > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned (seq_len, global_batch) workload points."""

    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int
    sliding: bool = False  # force the sliding-window attention variant


SHAPES = {
    "train_4k": InputShape("train_4k", "train", 4_096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32_768, 128),
    "long_500k": InputShape("long_500k", "decode", 524_288, 1, sliding=True),
}

ARCH_IDS = (
    "whisper_large_v3",
    "llama4_scout_17b_a16e",
    "chatglm3_6b",
    "deepseek_67b",
    "zamba2_2p7b",
    "starcoder2_3b",
    "granite_moe_1b_a400m",
    "qwen1p5_0p5b",
    "internvl2_1b",
    "mamba2_780m",
)

# CLI ids (as printed in the assignment) -> module names
ARCH_ALIASES = {
    "whisper-large-v3": "whisper_large_v3",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "chatglm3-6b": "chatglm3_6b",
    "deepseek-67b": "deepseek_67b",
    "zamba2-2.7b": "zamba2_2p7b",
    "starcoder2-3b": "starcoder2_3b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "qwen1.5-0.5b": "qwen1p5_0p5b",
    "internvl2-1b": "internvl2_1b",
    "mamba2-780m": "mamba2_780m",
    "cifar-supernet": "cifar_supernet",
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    """Load ``config()`` (or ``smoke_config()``) from the arch module."""
    mod_name = ARCH_ALIASES.get(arch, arch.replace("-", "_").replace(".", "p"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.smoke_config() if smoke else mod.config()


def get_shape(name: str) -> InputShape:
    return SHAPES[name]
