"""qwen1.5-0.5b [dense]: 24L d_model=1024 16H (GQA kv=16) d_ff=2816
vocab=151936, QKV bias.  [hf:Qwen/Qwen1.5-0.5B]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-0.5b",
        family="dense",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=2816,
        vocab_size=151936,
        rope_style="1d",
        qkv_bias=True,
        source="hf:Qwen/Qwen1.5-0.5B",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
        vocab_size=512, dtype="float32",
    )
