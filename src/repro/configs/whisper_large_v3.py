"""whisper-large-v3 [audio]: enc-dec transformer backbone, conv/mel frontend stubbed.

32L d_model=1280 20H (GQA kv=20) d_ff=5120 vocab=51866.  [arXiv:2212.04356]
The mel-spectrogram + conv feature extractor is a STUB: ``input_specs`` supplies
precomputed frame embeddings (1500 frames, the fixed 30 s Whisper window).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        family="audio",
        num_layers=32,            # decoder layers
        encoder_layers=32,
        d_model=1280,
        num_heads=20,
        num_kv_heads=20,
        d_ff=5120,
        vocab_size=51866,
        rope_style="none",        # whisper uses learned/sinusoidal positions
        qkv_bias=True,
        num_prefix=1500,          # audio frame embeddings from the stub frontend
        source="arXiv:2212.04356",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, encoder_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=512, num_prefix=16, dtype="float32",
    )
