"""internvl2-1b [vlm]: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
InternViT vision encoder + projector are a STUB: ``input_specs`` supplies
precomputed patch embeddings (256 patches) that the LM decoder consumes
(early-fusion prefix).  LM backbone is Qwen2-0.5B-like.  [arXiv:2404.16821]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        family="vlm",
        num_layers=24,
        d_model=896,
        num_heads=14,
        num_kv_heads=2,
        d_ff=4864,
        vocab_size=151655,
        rope_style="1d",
        qkv_bias=True,
        num_prefix=256,          # ViT patch embeddings from the stub frontend
        source="arXiv:2404.16821",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
        vocab_size=512, num_prefix=8, dtype="float32",
    )
