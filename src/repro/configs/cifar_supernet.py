"""The paper's own master model: CIFAR CNN supernet (Fig. 3).

Conv stem + 12 choice blocks (4 branches each: identity / residual /
inverted-residual / depthwise-separable) + FC head.  Channels
[64,64,64,128,128,128,256,256,256,512,512,512]; blocks 3, 6, 9 are
reduction blocks (channels double, spatial quartered).  BatchNorm affine
params and moving statistics are DISABLED per the paper (Section IV.C).
"""
from repro.configs.base import ModelConfig

# Output channels of the 12 choice blocks (paper Section IV.C).
CHANNELS = (64, 64, 64, 128, 128, 128, 256, 256, 256, 512, 512, 512)
IMAGE_SIZE = 32
NUM_CLASSES = 10
STEM_CHANNELS = 64


def config() -> ModelConfig:
    return ModelConfig(
        name="cifar-supernet",
        family="cnn",
        num_layers=12,           # choice blocks
        d_model=STEM_CHANNELS,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=NUM_CLASSES,
        supernet=True,
        num_branches=4,
        dtype="float32",
        source="this paper, Fig. 3 / Section IV.C",
    )


def smoke_config() -> ModelConfig:
    # 4 choice blocks, narrow channels — used by CPU tests and the example
    # drivers (the federated simulation is CPU-bound).
    return config().replace(num_layers=4)


# Reduced channel plan used when num_layers < 12 (smoke / CPU federation).
def channels_for(num_blocks: int):
    if num_blocks == 12:
        return CHANNELS
    plan = []
    c = 16
    for i in range(num_blocks):
        if i > 0 and i % 2 == 0:
            c *= 2
        plan.append(c)
    return tuple(plan)


def stem_channels_for(num_blocks: int) -> int:
    return STEM_CHANNELS if num_blocks == 12 else 16
