from repro.configs.base import (
    ARCH_ALIASES,
    ARCH_IDS,
    SHAPES,
    InputShape,
    ModelConfig,
    get_config,
    get_shape,
)

__all__ = [
    "ARCH_ALIASES",
    "ARCH_IDS",
    "SHAPES",
    "InputShape",
    "ModelConfig",
    "get_config",
    "get_shape",
]
