"""chatglm3-6b [dense]: 28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
RoPE applied to half the head dim (chatglm 2D-style), GQA.  [arXiv:2406.12793]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b",
        family="dense",
        num_layers=28,
        d_model=4096,
        num_heads=32,
        num_kv_heads=2,
        d_ff=13696,
        vocab_size=65024,
        rope_style="2d",
        qkv_bias=True,
        source="arXiv:2406.12793",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
        vocab_size=512, dtype="float32",
    )
