"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64.  Mamba2 backbone + shared attention block applied
periodically (zamba2 style).  [arXiv:2411.15242]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        d_ff=10240,
        vocab_size=32000,
        ssm_state=64,
        ssm_head_dim=80,
        attn_every=6,            # shared attn+mlp block every 6th mamba layer
        rope_style="1d",
        source="arXiv:2411.15242",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
        vocab_size=512, ssm_state=16, ssm_head_dim=32, attn_every=2,
        dtype="float32",
    )
