"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 (+ llama4 shared expert), early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=202048,
        num_experts=16,
        top_k=1,
        moe_d_ff=8192,
        shared_expert=True,
        rope_style="1d",
        rope_theta=500000.0,
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
        moe_d_ff=256, vocab_size=512, num_experts=4, top_k=1, dtype="float32",
    )
