"""deepseek-67b [dense]: 95L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=102400, llama-style architecture.  [arXiv:2401.02954]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b",
        family="dense",
        num_layers=95,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=22016,
        vocab_size=102400,
        rope_style="1d",
        source="arXiv:2401.02954",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
        vocab_size=512, dtype="float32",
    )
