"""starcoder2-3b [dense]: 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152.  GQA, RoPE, (starcoder2 also ships a 4k sliding window, which we
use for the long_500k shape).  [arXiv:2402.19173]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b",
        family="dense",
        num_layers=30,
        d_model=3072,
        num_heads=24,
        num_kv_heads=2,
        d_ff=12288,
        vocab_size=49152,
        rope_style="1d",
        qkv_bias=True,
        sliding_window=4096,
        source="arXiv:2402.19173",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
        vocab_size=512, sliding_window=64, dtype="float32",
    )
