"""Optimizers (pure pytree transforms) and learning-rate schedules.

SGD + momentum reproduces the paper's client optimizer (Table II: lr 0.1,
momentum 0.5, per-round decay 0.995).  AdamW is provided for the
(non-federated) LM training path of the launcher.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Params = Any


def sgd_init(params: Params) -> Params:
    return jax.tree.map(jnp.zeros_like, params)


def sgd_update(params: Params, grads: Params, vel: Params, lr,
               momentum: float = 0.5) -> Tuple[Params, Params]:
    vel = jax.tree.map(lambda v, g: momentum * v + g, vel, grads)
    params = jax.tree.map(lambda p, v: p - lr * v.astype(p.dtype), params, vel)
    return params, vel


def adamw_init(params: Params) -> Params:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, zeros),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(params: Params, grads: Params, state, lr,
                 b1=0.9, b2=0.95, eps=1e-8, wd=0.01):
    step = state["step"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                     state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                     * jnp.square(g.astype(jnp.float32)), state["v"], grads)
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, mh, vh):
        u = (mh / c1) / (jnp.sqrt(vh / c2) + eps) + wd * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    params = jax.tree.map(upd, params, m, v)
    return params, {"m": m, "v": v, "step": step}


def round_decay(lr0: float, decay: float, t) -> jnp.ndarray:
    """Paper Table II: lr(t) = lr0 * decay^t per communication round."""
    return jnp.asarray(lr0 * decay ** t, jnp.float32)


def cosine_decay(lr0: float, step, total: int, warmup: int = 0):
    step = jnp.asarray(step, jnp.float32)
    warm = lr0 * step / jnp.maximum(warmup, 1)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = lr0 * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < warmup, warm, cos)
