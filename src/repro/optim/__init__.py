from repro.optim.optim import (
    adamw_init, adamw_update, sgd_init, sgd_update, round_decay, cosine_decay,
)

__all__ = ["adamw_init", "adamw_update", "sgd_init", "sgd_update",
           "round_decay", "cosine_decay"]
