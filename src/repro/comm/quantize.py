"""Int8 payload codec: per-tensor symmetric quantization.

Every floating leaf is quantized independently with one float32 scale
``max|x| / 127``; values land on the 255-level symmetric grid
``{-127..127} * scale`` (so ``x == 0`` maps to exactly 0 and the maximum
round-trip error is ``scale / 2``).  Wire cost is 1 byte per parameter
plus ``SCALE_BYTES`` per tensor — the per-payload tensor count is not
recoverable from a parameter count alone, so ``wire_bytes`` charges one
amortized scale per payload (an O(tensors/params) underestimate, well
under 0.1% on the supernet masters).

``backend="pallas"`` routes the elementwise quantize/dequantize through
the ``repro.kernels.quantize`` Pallas TPU kernel (interpret-mode off-TPU,
like every kernel in this repo); ``"xla"`` routes through the
``repro.kernels.ref`` jnp oracles the kernel is swept against — one
definition of the grid math, so the routes cannot drift
(``tests/test_kernels.py`` / ``tests/test_comm.py``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.comm.codec import SCALE_BYTES, PayloadCodec, tree_map_float

QMAX = 127.0


def leaf_scale(x: jnp.ndarray) -> jnp.ndarray:
    """Per-tensor symmetric scale ``max|x| / 127`` (floored so an
    all-zero tensor round-trips to zeros instead of dividing by 0)."""
    return jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12) / QMAX


@jax.jit
def _roundtrip_xla(tree):
    from repro.kernels import ref

    def leaf(x):
        xf = x.astype(jnp.float32)
        scale = leaf_scale(xf)
        q = ref.quantize_int8(xf.reshape(-1), scale)
        return ref.dequantize_int8(q, scale).reshape(x.shape).astype(x.dtype)

    return tree_map_float(leaf, tree)


@jax.jit
def _roundtrip_pallas(tree):
    from repro.kernels import ops as kops

    def leaf(x):
        xf = x.reshape(-1).astype(jnp.float32)
        scale = leaf_scale(xf)
        q = kops.quantize_int8(xf, scale)
        return kops.dequantize_int8(q, scale).reshape(x.shape).astype(x.dtype)

    return tree_map_float(leaf, tree)


@dataclasses.dataclass(frozen=True)
class Int8Codec(PayloadCodec):
    """Per-tensor symmetric int8 quantization (1 B/param on the wire)."""

    name: str = "int8"
    backend: str = "xla"        # 'xla' | 'pallas' quantize/dequantize route

    def wire_bytes(self, n_params: int) -> float:
        return 1.0 * n_params + SCALE_BYTES

    def roundtrip(self, tree):
        fn = (_roundtrip_pallas if self.backend == "pallas"
              else _roundtrip_xla)
        return fn(tree)
