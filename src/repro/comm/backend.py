"""CodecBackend: encode→decode applied around any execution backend.

The execution backends (``loop`` / ``vmap`` / ``mesh``) answer *how*
client work is dispatched; this wrapper answers *what crosses the wire*
around those dispatches, uniformly for every strategy x backend pair:

  * **downlink** — every parameter tree a client receives (the master a
    round trains from / evaluates, the per-individual inits of the
    offline baseline) is replaced by its ``downlink.roundtrip`` — the
    reconstruction of the compressed broadcast.
  * **uplink** — the aggregated master update (what the fill-aggregated
    uploads change about the master, ``raw - sent_down``) is replaced by
    its error-feedback-compressed reconstruction
    (``repro.comm.error_feedback``): persistent-model paths
    (``train_fill``, Algorithm 3; ``train_fedavg``, Algorithm 1) carry a
    per-stream residual so the lossy uplink stays unbiased over rounds;
    the offline baseline's per-round reinitialized individuals are
    ephemeral, so their updates get a plain (residual-free) roundtrip.

Compression is simulated at the aggregate boundary — per-client wire
*bytes* are still charged per upload by the strategies' ``CommStats``
accounting, but the information loss is applied once to the aggregated
update.  That choice is what guarantees backend parity: the transform is
a deterministic function of the (already parity-tested) aggregate, so
``loop``/``vmap``/``mesh`` keep producing identical CommStats and
masters within the usual 1e-5 under any codec, and the fused mesh
shard_map programs stay intact.

The wrapper implements the full ``ExecutionBackend`` protocol (and
proxies ``dispatches``), so ``FedEngine`` treats it as just another
backend; it is only constructed when at least one codec is not
``"none"``, so codec-free runs take the exact pre-subsystem path.
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence

import jax
import numpy as np

from repro.comm.codec import PayloadCodec
from repro.comm.error_feedback import ErrorFeedback, _tree_add, _tree_sub
from repro.obs import NULL_TELEMETRY

Params = Any


class CodecBackend:
    """Wrap ``inner`` with uplink/downlink payload codecs."""

    # shared no-op unless FedEngine attaches a real Telemetry (repro.obs)
    telemetry = NULL_TELEMETRY

    def __init__(self, inner, uplink: PayloadCodec, downlink: PayloadCodec):
        self.inner = inner
        self.uplink = uplink
        self.downlink = downlink
        self._ef = {"fill": ErrorFeedback(uplink),
                    "fedavg": ErrorFeedback(uplink)}

    # -- engine plumbing -----------------------------------------------------

    @property
    def name(self) -> str:
        return self.inner.name

    @property
    def dispatches(self) -> int:
        return self.inner.dispatches

    @dispatches.setter
    def dispatches(self, value: int) -> None:
        self.inner.dispatches = value

    def reset(self) -> None:
        """Drop error-feedback residuals (``FedEngine.run`` re-entrancy)."""
        for ef in self._ef.values():
            ef.reset()

    # -- codec application ---------------------------------------------------

    def _down(self, params: Params) -> Params:
        # telemetry "codec_decode": the downlink roundtrip — what every
        # client reconstructs from the compressed broadcast (nests under
        # fill_train/eval when the InstrumentedBackend wraps this one)
        with self.telemetry.span("codec_decode"):
            return self.downlink.roundtrip(params)

    def _up(self, sent_down: Params, raw: Params,
            stream: Optional[str] = None) -> Params:
        """Receiver-side master after the uplink codec: ``sent_down`` plus
        the (EF-)compressed reconstruction of ``raw - sent_down``.
        ``stream`` names the error-feedback residual to carry; ``None``
        (ephemeral models) compresses without a residual."""
        if self.uplink.is_identity:
            return raw
        # telemetry "codec_encode": the (error-feedback) uplink
        # compression of the aggregated update
        with self.telemetry.span("codec_encode"):
            delta = _tree_sub(raw, sent_down)
            sent = self._ef[stream].step(delta) if stream is not None \
                else self.uplink.roundtrip(delta)
            new = _tree_add(sent_down, sent)
            return jax.tree.map(lambda n, r: n.astype(r.dtype), new, raw)

    # -- ExecutionBackend protocol -------------------------------------------

    def train_fill(self, master: Params, keys, groups, lr: float,
                   survivors=None) -> Params:
        m_down = self._down(master)
        raw = self.inner.train_fill(m_down, keys, groups, lr,
                                    survivors=survivors)
        return self._up(m_down, raw, "fill")

    def train_fedavg(self, params: Params, key, client_ids,
                     lr: float, survivors=None) -> Params:
        p_down = self._down(params)
        raw = self.inner.train_fedavg(p_down, key, client_ids, lr,
                                      survivors=survivors)
        return self._up(p_down, raw, "fedavg")

    def train_fedavg_population(self, params_list: Sequence[Params], keys,
                                client_ids, lr: float,
                                survivors=None) -> List[Params]:
        downs = [self._down(p) for p in params_list]
        raws = self.inner.train_fedavg_population(downs, keys, client_ids,
                                                  lr, survivors=survivors)
        return [self._up(d, r, stream=None) for d, r in zip(downs, raws)]

    def eval_shared(self, params: Params, keys, client_ids,
                    survivors=None) -> np.ndarray:
        return self.inner.eval_shared(self._down(params), keys, client_ids,
                                      survivors=survivors)

    def eval_paired(self, params_list: Sequence[Params], keys,
                    client_ids, survivors=None) -> np.ndarray:
        return self.inner.eval_paired([self._down(p) for p in params_list],
                                      keys, client_ids, survivors=survivors)
