"""repro.comm: payload codecs for the federated wire.

What one sub-model payload costs on the wire (``PayloadCodec.wire_bytes``
-> ``CommStats`` wire-byte accounting) and what the receiver
reconstructs (``PayloadCodec.roundtrip``), composed with server-side
error feedback (``ErrorFeedback``) and applied around any execution
backend by ``CodecBackend``.  Select codecs per direction with
``RunConfig(uplink_codec=..., downlink_codec=...)``; specs are validated
at config time via ``make_codec``.  See docs/architecture.md
("Communication codecs").
"""
from repro.comm.backend import CodecBackend
from repro.comm.codec import (
    CODEC_NAMES, CastCodec, PayloadCodec, make_codec,
)
from repro.comm.error_feedback import ErrorFeedback
from repro.comm.quantize import Int8Codec
from repro.comm.sparsify import TopKCodec

__all__ = [
    "CODEC_NAMES", "CastCodec", "CodecBackend", "ErrorFeedback",
    "Int8Codec", "PayloadCodec", "TopKCodec", "make_codec",
]
