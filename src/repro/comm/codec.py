"""Payload codecs: what one parameter payload costs on the wire, and what
the receiver reconstructs.

The paper's headline requirement is that real-time federated NAS "reduce
the local payload"; this module makes the payload encoding a first-class,
pluggable axis next to the execution backend.  A ``PayloadCodec`` answers
two questions:

  * ``wire_bytes(n_params)`` — bytes one encoded payload of ``n_params``
    parameters occupies on the wire (``CommStats`` wire-byte accounting;
    deterministic and backend-independent, so every execution backend
    reports identical stats).
  * ``roundtrip(tree)``      — ``decode(encode(tree))`` as one on-device
    transform: the *reconstruction* the receiver would see.  The runtime
    simulates federation on one host, so the wire format itself is never
    materialized — only its information loss (and its byte cost) are.

Codecs are pure and stateless; server-side error-feedback state lives in
``repro.comm.error_feedback`` and the engine wiring in
``repro.comm.backend``.  Specs are strings validated at ``RunConfig``
construction time (same pattern as ``aggregate_backend``):

    "none"                      fp32 passthrough (4 B/param)
    "cast" | "cast:bf16"        bfloat16 cast (2 B/param)
    "cast:fp16"                 float16 cast (2 B/param)
    "int8" | "int8:pallas"      per-tensor symmetric int8 quantization
                                (1 B/param + 4 B scale per tensor;
                                ":pallas" routes the quantize/dequantize
                                through the ``repro.kernels.quantize``
                                Pallas kernel, ":xla" / bare through the
                                jnp reference)
    "topk" | "topk:<ratio>"     magnitude top-k sparsification (8 B per
                                kept (index, value) pair; default ratio
                                0.1)

Only floating-point leaves are transformed; integer/bool leaves (none in
the current master trees) pass through untouched and are charged fp32
wire bytes.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any

CODEC_NAMES = ("none", "cast", "int8", "topk")

SCALE_BYTES = 4         # one float32 scale per quantized tensor
TOPK_ENTRY_BYTES = 8    # int32 flat index + float32 value per kept entry


def _is_float(x) -> bool:
    return jnp.issubdtype(jnp.asarray(x).dtype, jnp.inexact)


def tree_map_float(fn, tree: Params) -> Params:
    """Apply ``fn`` to floating leaves, pass the rest through."""
    return jax.tree.map(lambda x: fn(x) if _is_float(x) else x, tree)


@dataclasses.dataclass(frozen=True)
class PayloadCodec:
    """Base codec: fp32 passthrough (``"none"``).

    Frozen dataclasses so codecs hash/compare by configuration — two
    engines built from the same ``RunConfig`` share jit caches.
    """

    name: str = "none"

    def wire_bytes(self, n_params: int) -> float:
        """Wire size of one encoded payload of ``n_params`` parameters."""
        return 4.0 * n_params

    def roundtrip(self, tree: Params) -> Params:
        """``decode(encode(tree))`` — the receiver's reconstruction."""
        return tree

    @property
    def is_identity(self) -> bool:
        return type(self) is PayloadCodec


@dataclasses.dataclass(frozen=True)
class CastCodec(PayloadCodec):
    """Downcast to a 16-bit float on the wire (2 B/param), upcast back."""

    name: str = "cast"
    dtype: str = "bf16"     # "bf16" | "fp16"

    def wire_bytes(self, n_params: int) -> float:
        return 2.0 * n_params

    def roundtrip(self, tree: Params) -> Params:
        wire = jnp.bfloat16 if self.dtype == "bf16" else jnp.float16
        return tree_map_float(
            lambda x: x.astype(wire).astype(x.dtype), tree)


def make_codec(spec: str) -> PayloadCodec:
    """Build a codec from its string spec; raise ``ValueError`` (with the
    available names) on anything unknown — called by
    ``RunConfig.__post_init__`` so bad specs fail at config time."""
    from repro.comm.quantize import Int8Codec
    from repro.comm.sparsify import TopKCodec

    if not isinstance(spec, str):
        raise ValueError(f"codec spec must be a string, got {spec!r}")
    head, _, arg = spec.partition(":")
    if head == "none" and not arg:
        return PayloadCodec()
    if head == "cast":
        if arg in ("", "bf16", "fp16"):
            return CastCodec(dtype=arg or "bf16")
        raise ValueError(
            f"unknown cast dtype {arg!r} in codec spec {spec!r}; "
            f"available: ['bf16', 'fp16']")
    if head == "int8":
        if arg in ("", "xla", "pallas"):
            return Int8Codec(backend=arg or "xla")
        raise ValueError(
            f"unknown int8 backend {arg!r} in codec spec {spec!r}; "
            f"available: ['xla', 'pallas']")
    if head == "topk":
        if not arg:
            return TopKCodec()
        try:
            ratio = float(arg)
        except ValueError:
            ratio = -1.0
        if not 0.0 < ratio <= 1.0:
            raise ValueError(
                f"topk ratio must be in (0, 1], got {arg!r} "
                f"in codec spec {spec!r}")
        return TopKCodec(ratio=ratio)
    raise ValueError(
        f"unknown payload codec {spec!r}; available: {list(CODEC_NAMES)}")
