"""Top-k payload codec: magnitude sparsification.

Each floating leaf keeps its ``k = max(1, round(ratio * size))``
largest-magnitude entries (flat ``lax.top_k`` indices, so ties resolve
deterministically by position) and zeroes the rest.  The wire carries one
(int32 flat index, float32 value) pair per kept entry —
``TOPK_ENTRY_BYTES`` each — i.e. ``8 * ratio`` bytes per parameter.

Top-k is a *biased* compressor (it systematically drops small
coordinates), so on the uplink it is composed with the server-side
error-feedback residual in ``repro.comm.error_feedback`` — what is
dropped this round is carried into the next round's payload, and
Algorithm 3's fill-aggregation stays unbiased over rounds.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.comm.codec import TOPK_ENTRY_BYTES, PayloadCodec, tree_map_float


def leaf_k(size: int, ratio: float) -> int:
    """Entries kept for a ``size``-element tensor (always at least 1)."""
    return max(1, min(size, int(round(ratio * size))))


@functools.partial(jax.jit, static_argnames=("ratio",))
def _roundtrip(tree, ratio: float):
    def leaf(x):
        xf = x.reshape(-1).astype(jnp.float32)
        k = leaf_k(xf.size, ratio)
        _, idx = jax.lax.top_k(jnp.abs(xf), k)
        out = jnp.zeros_like(xf).at[idx].set(xf[idx])
        return out.reshape(x.shape).astype(x.dtype)

    return tree_map_float(leaf, tree)


@dataclasses.dataclass(frozen=True)
class TopKCodec(PayloadCodec):
    """Keep the ``ratio`` largest-magnitude entries per tensor."""

    name: str = "topk"
    ratio: float = 0.1

    def wire_bytes(self, n_params: int) -> float:
        return TOPK_ENTRY_BYTES * leaf_k(max(n_params, 1), self.ratio)

    def roundtrip(self, tree):
        return _roundtrip(tree, self.ratio)
