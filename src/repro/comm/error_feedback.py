"""Server-side error feedback for lossy uplink codecs.

Lossy compressors are biased (top-k systematically, int8/cast by
rounding); applied round after round to Algorithm 3's master update the
bias would accumulate.  Error feedback (Seide et al. 2014; Karimireddy
et al. 2019) fixes that by carrying the compression error forward:

    sent_t     = C(delta_t + residual_{t-1})
    residual_t = (delta_t + residual_{t-1}) - sent_t

so the applied updates *telescope*:

    sum_t sent_t = sum_t delta_t + residual_0 - residual_T

— the cumulative applied update differs from the cumulative true update
by exactly the final residual, a single-step compression error that does
not grow with T (asserted by ``tests/test_comm.py``).

The residual lives on the *server*: this runtime's clients are ephemeral
(double sampling redraws the client groups every round), so the one
persistent place compression error can be carried is around the
aggregated master update — ``repro.comm.backend.CodecBackend`` applies
``step`` to the fill-aggregated delta, which also keeps the transform
identical (and therefore parity-safe) across the loop/vmap/mesh
execution backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.comm.codec import PayloadCodec, tree_map_float


def _zeros_like_float(tree):
    return tree_map_float(
        lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def _float_op(op):
    """Elementwise float32 op on floating leaves; non-float leaves (none
    in the current master trees) pass the first argument through."""
    def leaf(x, y):
        if not jnp.issubdtype(jnp.asarray(x).dtype, jnp.inexact):
            return x
        return op(x.astype(jnp.float32), y.astype(jnp.float32))

    return jax.jit(lambda a, b: jax.tree.map(leaf, a, b))


_tree_add = _float_op(jnp.add)
_tree_sub = _float_op(jnp.subtract)


class ErrorFeedback:
    """One compression stream's residual state (reset per ``run()``)."""

    def __init__(self, codec: PayloadCodec):
        self.codec = codec
        self.residual = None

    def reset(self) -> None:
        self.residual = None

    def step(self, delta):
        """Compress ``delta`` with the carried residual folded in; update
        the residual; return what the receiver reconstructs."""
        if self.codec.is_identity:
            return delta
        if self.residual is None:
            self.residual = _zeros_like_float(delta)
        target = _tree_add(delta, self.residual)
        sent = self.codec.roundtrip(target)
        self.residual = _tree_sub(target, sent)
        return sent
