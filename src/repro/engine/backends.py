"""Pluggable client-execution backends for the federated engine.

A backend answers four questions for a strategy — *how* to run local SGD
and evaluation, never *what* to run (sampling, accounting and selection
live in the strategies / engine, so every backend sees the same inputs):

  * ``train_fill``   — train keys[i]'s sub-model on client group i from a
    shared master and fill-aggregate the uploads (Algorithm 3/4).
  * ``train_fedavg`` / ``train_fedavg_population`` — train one (or each)
    standalone model on every listed client and FedAvg per model
    (Algorithm 1 / the offline baseline).
  * ``eval_shared`` / ``eval_paired`` — weighted test error of K keys on a
    shared master, or of K (params, key) pairs.

``LoopBackend`` is the reference: one jitted dispatch per
(individual, client) pair, exactly the pre-engine semantics.
``VmapBackend`` stacks each same-shape client group into a ``ClientBatch``
and runs all population x client updates — and all 2N x participants
evaluations — in O(population) jitted dispatches per generation,
constant in the number of participating clients.  ``MeshBackend``
(``repro.engine.mesh_backend``) additionally shards the population axis
of those stacks over a jax device mesh.  All backends count
``dispatches`` so tests and benchmarks can assert those claims instead
of trusting them.

With ``RunConfig.fused`` (the default) the batched backends collapse
further, to a *constant* number of dispatches per generation: the whole
population's choice keys are stacked into one (P, num_blocks) device
array and a single jitted program per ``train_fill`` runs the local-SGD
scan, the per-group weighting and the Algorithm 3 partial sums for
every individual (master passed with ``donate_argnums`` off-CPU so the
per-generation master update reuses its buffers — see
``master_donation_safe``), while a single evaluation program takes the
master plus all stacked keys and returns the on-device wrong-count
vector, fetched with one ``jax.device_get`` per generation instead of
2N x buckets blocking ``int(...)`` syncs.  The shared program bodies
(``fill_bucket_partial``, ``eval_bucket_counts``, ...) live here;
``MeshBackend`` composes the same bodies with its ``shard_map``/``psum``
structure, so the fused sharded path is O(1) dispatches per generation.

Every backend routes Algorithm 3 through ``RunConfig.aggregate_backend``
identically: ``"xla"`` is the jnp reference, ``"pallas"`` the
``repro.kernels.fill_aggregate`` TPU kernel (interpret-mode off-TPU).
Unknown values are rejected by ``RunConfig`` at construction time.

Payload codecs never appear in this module: when
``RunConfig.uplink_codec`` / ``downlink_codec`` select a lossy codec,
``FedEngine`` wraps whichever backend it built in
``repro.comm.backend.CodecBackend``, which applies encode->decode around
these train/eval entry points uniformly — so the dispatch math here (and
in ``mesh_backend``) stays codec-free and every backend sees identical
compressed inputs.

Client availability (``ClientSimConfig``) reaches every entry point as
an optional ``survivors`` set — the clients whose uploads actually
arrive this round.  The batched backends keep their program shapes
STATIC under dropout: dropped clients stay in the stacked arrays and are
masked out instead — for training their aggregation weight is zeroed
host-side (exactly the weight-0 padding-row mechanism, so
``fill_bucket_partial`` / ``fedavg_population_bucket`` need no new
arguments and a zeroed row contributes *exactly* nothing), with the
normalization total taken over survivors only; for evaluation an int32
``alive`` mask rides into ``eval_bucket_counts`` and multiplies the
per-client wrong counts (integer math — masking is exact).  The fused
dispatch count is therefore unchanged at any dropout rate.  The loop
backend simply skips dead clients, which the weight-0/masked paths
reproduce exactly.  ``survivors=None`` (the default) is the legacy
fully-synchronous path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Protocol, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import fedavg, fill_aggregate, \
    fill_aggregate_stacked, fill_partial
from repro.core.federated import client_update_fn, eval_count_fn, \
    weighted_test_error
from repro.core.supernet import SupernetAPI
from repro.data.pipeline import ClientBatch, ClientDataset, shape_buckets
from repro.engine.types import RunConfig
from repro.obs import NULL_TELEMETRY, traced

Params = Any


def master_donation_safe(cfg: RunConfig) -> bool:
    """Whether a fused ``train_fill`` may pass the master pytree with
    ``donate_argnums`` (reusing its buffers for the updated master).

    Donation invalidates the caller's master after the dispatch.  Every
    strategy overwrites its master with ``train_fill``'s return value,
    so the only reader of the *old* buffers is ``CodecBackend``: with a
    lossy uplink codec it re-reads the downlinked master to form the
    uplink delta (``raw - sent_down``) after the inner call.  Hence:
    donation is safe iff the uplink codec is the identity.  (The jit
    donation itself is additionally gated on a non-CPU jax backend at
    construction time — CPU XLA cannot reuse donated buffers and would
    warn on every dispatch.)"""
    from repro.comm import make_codec
    return make_codec(cfg.uplink_codec).is_identity


# ---------------------------------------------------------------------------
# Fused-generation program bodies (shared by VmapBackend and MeshBackend)
# ---------------------------------------------------------------------------
#
# Each body consumes ONE shape bucket of group-major stacked arrays (see
# StackedClientBase._group_bucket_arrays) and keeps every choice key a
# traced *scalar* via lax.scan, so lax.switch in the model forward stays
# a real branch (vmapping the key axis would lower to compute-all-
# branches-and-select; benchmarks/fed_nas.py re-measures that trade per
# phase — see docs/architecture.md "Fused generations").  MeshBackend
# wraps the same bodies in shard_map (+ psum for train), which is what
# keeps the loop/vmap/mesh float32 reduction orders aligned.

def fill_bucket_partial(upd, mask_fn, master, keys, xb, yb, w, lr):
    """Fused local SGD + Algorithm 3 partial sum over one shape bucket.

    ``keys`` (G, num_blocks) int32; ``xb``/``yb`` (G, S, nbat, B, ...);
    ``w`` (G, S) float32 globally normalized (0 = padding).  Scans over
    the G groups; per group, scans local SGD over the S clients and
    reduces with ``aggregate.fill_partial`` — the same expression the
    non-fused stacked aggregator uses.  Returns the float32 partial-sum
    tree (callers add buckets and cast back to the master dtypes)."""

    def per_group(acc, inp):
        key, gx, gy, gw = inp

        def per_client(_, c):
            return None, upd(master, key, c[0], c[1], lr)

        # named_scope labels (profiler captures / HLO dumps only — they
        # never change numerics): the local-SGD scan vs the Algorithm 3
        # partial-sum reduction inside the fused fill program
        with jax.named_scope("local_sgd"):
            outs = jax.lax.scan(per_client, None, (gx, gy))[1]
        with jax.named_scope("fill_aggregate"):
            keys_s = jnp.broadcast_to(key, (gw.shape[0],) + key.shape)
            masks = jax.vmap(mask_fn)(outs, keys_s)
            part = fill_partial(master, outs, masks, gw)
            return jax.tree.map(jnp.add, acc, part), None

    zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), master)
    return jax.lax.scan(per_group, zeros, (keys, xb, yb, w))[0]


def train_bucket_uploads(upd, master, keys, xb, yb, lr):
    """Fused local SGD over one bucket, uploads returned stacked
    (G, S, ...) — the ``aggregate_backend='pallas'`` route, where
    Algorithm 3 runs in the ``repro.kernels.fill_aggregate`` kernel
    outside this program."""

    def per_group(_, inp):
        key, gx, gy = inp

        def per_client(__, c):
            return None, upd(master, key, c[0], c[1], lr)

        return None, jax.lax.scan(per_client, None, (gx, gy))[1]

    return jax.lax.scan(per_group, None, (keys, xb, yb))[1]


def _tiled_count(ev, params, key, xb, yb, alive, tile):
    """Wrong count of one (params, key) pair over a stacked test bucket,
    with the client axis consumed ``tile`` shards per scan step through
    an inner ``vmap`` (forward-only compute is cheap enough for moderate
    batching to pay — the same trade ``RunConfig.vmap_eval_tile`` makes
    on the non-fused path).  ``alive`` is the (S,) int32 survivor mask
    multiplying each client's count (1s when the availability simulation
    is off).  Counts are integers, so tiling and masking are both
    exact."""
    m = xb.shape[0]
    tile = max(1, min(tile, m))
    full = (m // tile) * tile
    tile_ev = jax.vmap(ev, in_axes=(None, None, 0, 0))
    acc = jnp.zeros((), jnp.int32)
    # named_scope labels (profiler captures / HLO dumps only — they
    # never change numerics) for the masked client-axis count scans
    if full:
        fx = xb[:full].reshape((full // tile, tile) + xb.shape[1:])
        fy = yb[:full].reshape((full // tile, tile) + yb.shape[1:])
        fa = alive[:full].reshape((full // tile, tile))

        def tiles(a, c):
            return a + jnp.sum(c[2] * tile_ev(params, key, c[0], c[1])), None

        with jax.named_scope("eval_count_tiles"):
            acc = jax.lax.scan(tiles, acc, (fx, fy, fa))[0]
    if m > full:
        def tail(a, c):
            return a + c[2] * ev(params, key, c[0], c[1]), None

        with jax.named_scope("eval_count_tail"):
            acc = jax.lax.scan(tail, acc,
                               (xb[full:], yb[full:], alive[full:]))[0]
    return acc


def eval_bucket_counts(ev, params, keys, xb, yb, alive, tile=1):
    """Wrong counts of every key on one shared master over one stacked
    test bucket: ``keys`` (K, num_blocks) -> (K,) int32 on device.  The
    key axis is consumed by ``lax.scan`` (scalar keys keep ``lax.switch``
    a real branch); the client axis is tiled (``_tiled_count``) and
    masked by the (S,) int32 ``alive`` survivor vector."""

    def per_key(_, key):
        return None, _tiled_count(ev, params, key, xb, yb, alive, tile)

    return jax.lax.scan(per_key, None, keys)[1]


def eval_paired_bucket_counts(ev, ps, keys, xb, yb, alive, tile=1):
    """``eval_bucket_counts`` for (params, key) pairs: every ``ps`` leaf
    carries a leading (K,) axis aligned with ``keys``."""

    def per_pair(_, inp):
        p, key = inp
        return None, _tiled_count(ev, p, key, xb, yb, alive, tile)

    return jax.lax.scan(per_pair, None, (ps, keys))[1]


def fedavg_population_bucket(upd, ps, keys, xb, yb, wn, lr):
    """Per-individual FedAvg partial sums over one train bucket: ``ps``
    leaves (P, ...), ``keys`` (P, nb); ``xb``/``yb`` (S, nbat, B, ...)
    and ``wn`` (S,) normalized weights shared by every individual.
    Mirrors the non-fused ``scan_update_avg`` (stacked outs, one
    weighted ``jnp.sum``) so reduction order matches across paths."""

    def per_ind(_, inp):
        p, key = inp

        def per_client(__, c):
            return None, upd(p, key, c[0], c[1], lr)

        outs = jax.lax.scan(per_client, None, (xb, yb))[1]

        def avg(x):
            wr = wn.reshape((-1,) + (1,) * (x.ndim - 1))
            return jnp.sum(wr * x.astype(jnp.float32), axis=0)

        return None, jax.tree.map(avg, outs)

    return jax.lax.scan(per_ind, None, (ps, keys))[1]


def accumulate_parts(parts):
    """Sum an iterable of identically-shaped pytrees (a bare array is a
    one-leaf pytree) — the bucket combiner of every fused program."""
    acc = None
    for part in parts:
        acc = part if acc is None else jax.tree.map(jnp.add, acc, part)
    return acc


def cast_like(tree, ref):
    """Cast every leaf of the float32 accumulator back to ``ref``'s
    dtypes (the fused programs' final step — with a donated master, the
    output reuses ``ref``'s buffers)."""
    return jax.tree.map(lambda a, r: a.astype(r.dtype), tree, ref)


class ExecutionBackend(Protocol):
    """The dispatch contract every backend implements.

    ``dispatches`` counts jitted device dispatches issued so far (the
    scaling claims in docs/architecture.md are asserted against it).
    All ``keys`` are (num_blocks,) int32 choice keys; ``client_ids`` /
    ``groups`` index into the backend's client list; ``lr`` is the
    round's learning rate.  ``survivors`` is ``None`` (every client
    completes — the legacy path) or the set of client ids whose uploads
    arrive this round (``ClientSimConfig`` dropout): non-survivors must
    contribute nothing to aggregation or error counts, with weights
    renormalized over survivors.  Returned parameters are full pytrees;
    ``eval_*`` return (len(keys),) float64 weighted test-error rates in
    [0, 1] over the surviving participants."""

    name: str
    dispatches: int

    def train_fill(self, master: Params, keys: Sequence[np.ndarray],
                   groups: Sequence[np.ndarray], lr: float,
                   survivors=None) -> Params:
        """Train keys[g] on client group g from the shared master and
        fill-aggregate the surviving uploads into the new master
        (Algorithm 3/4); groups may be empty (their individuals'
        blocks are filled from the previous master).  Callers must
        treat ``master`` as consumed — fused backends may donate its
        buffers to the returned update (``master_donation_safe``)."""
        ...

    def train_fedavg(self, params: Params, key: np.ndarray,
                     client_ids: np.ndarray, lr: float,
                     survivors=None) -> Params:
        """One FedAvg round of ``key``'s standalone model over every
        listed client (Algorithm 1)."""
        ...

    def train_fedavg_population(self, params_list: Sequence[Params],
                                keys: Sequence[np.ndarray],
                                client_ids: np.ndarray,
                                lr: float, survivors=None) -> List[Params]:
        """``train_fedavg`` for each (params, key) pair — every client
        trains every individual (the offline baseline)."""
        ...

    def eval_shared(self, params: Params, keys: Sequence[np.ndarray],
                    client_ids: np.ndarray, survivors=None) -> np.ndarray:
        """Weighted test-error rate of every key on one shared master."""
        ...

    def eval_paired(self, params_list: Sequence[Params],
                    keys: Sequence[np.ndarray],
                    client_ids: np.ndarray, survivors=None) -> np.ndarray:
        """Weighted test-error rate of every (params, key) pair."""
        ...


# ---------------------------------------------------------------------------
# Reference backend: one dispatch per pair
# ---------------------------------------------------------------------------

class LoopBackend:
    """Reference execution: one jitted dispatch per (individual, client)
    pair — exactly the pre-engine (per-pair Python loop) semantics that
    the batched backends are tested against.  Algorithm 3 routes through
    ``fill_aggregate(backend=cfg.aggregate_backend)``."""

    name = "loop"
    # shared no-op unless FedEngine attaches a real Telemetry (repro.obs)
    telemetry = NULL_TELEMETRY

    def __init__(self, api: SupernetAPI, clients: Sequence[ClientDataset],
                 cfg: RunConfig):
        self.api = api
        self.clients = clients
        self.cfg = cfg
        # the same programs make_client_update/make_evaluator build, with
        # a per-program trace counter + named_scope label around the body
        # (repro.obs.traced — tracing runs the Python wrapper, cached
        # dispatches don't, so the counts are recompile truth)
        self.trace_counts: dict = {}
        self.update = jax.jit(traced(
            "client_update", self.trace_counts,
            client_update_fn(api, cfg.local_epochs, cfg.momentum)))
        self.evaluate = jax.jit(traced(
            "evaluator", self.trace_counts, eval_count_fn(api)))
        self.dispatches = 0

    @staticmethod
    def _alive(survivors, cid) -> bool:
        return survivors is None or int(cid) in survivors

    def train_fill(self, master, keys, groups, lr, survivors=None):
        uploads = []
        for key, group in zip(keys, groups):
            jkey = np.asarray(key, np.int32)
            for cid in group:
                if not self._alive(survivors, cid):
                    continue          # dropped: its upload never arrives
                c = self.clients[int(cid)]
                xb, yb = c.train
                p_k = self.update(master, jkey, xb, yb, lr)
                self.dispatches += 1
                uploads.append((p_k, self.api.trained_mask(p_k, key),
                                c.weight))
        if not uploads:
            return master
        self.dispatches += 1
        return fill_aggregate(master, uploads,
                              backend=self.cfg.aggregate_backend)

    def train_fedavg(self, params, key, client_ids, lr, survivors=None):
        jkey = np.asarray(key, np.int32)
        uploads = []
        for cid in client_ids:
            if not self._alive(survivors, cid):
                continue
            c = self.clients[int(cid)]
            xb, yb = c.train
            uploads.append((self.update(params, jkey, xb, yb, lr), c.weight))
            self.dispatches += 1
        if not uploads:
            return params
        self.dispatches += 1
        return fedavg(uploads)

    def train_fedavg_population(self, params_list, keys, client_ids, lr,
                                survivors=None):
        return [self.train_fedavg(p, k, client_ids, lr, survivors=survivors)
                for p, k in zip(params_list, keys)]

    def eval_shared(self, params, keys, client_ids, survivors=None):
        part = [self.clients[int(i)] for i in client_ids
                if self._alive(survivors, i)]
        if not part:                   # nobody evaluated: pessimistic 1.0
            return np.ones(len(keys))
        errs = []
        for k in keys:
            errs.append(weighted_test_error(
                self.evaluate, params, np.asarray(k, np.int32), part))
            self.dispatches += len(part)
        return np.asarray(errs)

    def eval_paired(self, params_list, keys, client_ids, survivors=None):
        part = [self.clients[int(i)] for i in client_ids
                if self._alive(survivors, i)]
        if not part:                   # nobody evaluated: pessimistic 1.0
            return np.ones(len(keys))
        errs = []
        for p, k in zip(params_list, keys):
            errs.append(weighted_test_error(
                self.evaluate, p, np.asarray(k, np.int32), part))
            self.dispatches += len(part)
        return np.asarray(errs)


# ---------------------------------------------------------------------------
# Shared stacking/caching for the batched (vmap, mesh) backends
# ---------------------------------------------------------------------------

class StackedClientBase:
    """Host-side stacking, bucketing and caching shared by the batched
    execution backends (``VmapBackend``, ``MeshBackend``): stack-on-demand
    stacked train-shard stores keyed by the round's sampled clients,
    per-group gathers from them, and a memoized stacked test set per
    participant set.  Only sampled clients are ever stacked (or, with a
    lazy ``ClientFleet``, even materialized) — device memory scales with
    participation, never fleet size.  Subclasses implement the
    ``ExecutionBackend`` protocol on top."""

    # shared no-op unless FedEngine attaches a real Telemetry (repro.obs)
    telemetry = NULL_TELEMETRY

    def __init__(self, api: SupernetAPI, clients: Sequence[ClientDataset],
                 cfg: RunConfig):
        self.api = api
        self.clients = clients
        self.cfg = cfg
        self._test_cache = {}
        self._train_cache = {}
        self.dispatches = 0
        # per-jitted-program trace counts (repro.obs.traced) and LRU
        # hit/miss counters for the stacked-store caches — read by the
        # telemetry round gauges, free when telemetry is off
        self.trace_counts: dict = {}
        self.cache_stats = {"train_store_hits": 0, "train_store_misses": 0,
                            "test_stack_hits": 0, "test_stack_misses": 0}

    def _stack(self, client_ids, split):
        return ClientBatch.stack([self.clients[int(i)] for i in client_ids],
                                 split=split)

    def _group_batches(self, client_ids, split):
        """Yield ClientBatches for one client group, bucketed by shape."""
        shapes = [(self.clients[int(i)].train if split == "train"
                   else self.clients[int(i)].test)[0].shape
                  for i in client_ids]
        for idxs in shape_buckets(shapes):
            yield self._stack([client_ids[i] for i in idxs], split)

    def _train_store(self, client_ids):
        """Device-resident stacked train shards for ``client_ids`` ONLY:
        [(cid -> row, xb, yb)] per shape bucket, built on demand and
        kept in a size-2 LRU keyed by the canonical (sorted,
        deduplicated) id tuple — the same policy as ``_test_batches``.
        Stacking just the round's sampled clients is what keeps device
        memory proportional to participation x population rather than
        ``num_clients`` (and what lets a lazy ``ClientFleet`` leave the
        rest of a 10^6-client fleet unmaterialized); shards are
        immutable, so entries never go stale, full participation hits
        the same key every round, and alternating participant sets keep
        both LRU slots live."""
        key = tuple(sorted({int(i) for i in client_ids}))
        cache = self._train_cache
        if key in cache:
            cache[key] = cache.pop(key)      # refresh recency (true LRU)
            self.cache_stats["train_store_hits"] += 1
        else:
            self.cache_stats["train_store_misses"] += 1
            if len(cache) >= 2:
                cache.pop(next(iter(cache)))  # evict least-recently-used
            # a miss is the round's host->device download of the sampled
            # clients' train shards — the telemetry "download" phase
            with self.telemetry.span("download"):
                shards = [self.clients[i].train for i in key]
                store = []
                for idxs in shape_buckets([s[0].shape for s in shards]):
                    xb = jnp.stack([jnp.asarray(shards[i][0])
                                    for i in idxs])
                    yb = jnp.stack([jnp.asarray(shards[i][1])
                                    for i in idxs])
                    store.append(({key[i]: row
                                   for row, i in enumerate(idxs)}, xb, yb))
                cache[key] = store
        return cache[key]

    def _client_weight(self, cid, survivors) -> float:
        """A client's aggregation weight this round: 0 for dropped
        clients, so they stay in the static stacked shapes but
        contribute exactly nothing (the weight-0 padding mechanism)."""
        cid = int(cid)
        if survivors is not None and cid not in survivors:
            return 0.0
        return self.clients[cid].weight

    def _survivor_total(self, client_ids, survivors) -> float:
        """Sum of surviving weights — the renormalization total."""
        return float(sum(self._client_weight(c, survivors)
                         for c in client_ids))

    def _group_train_gather(self, client_ids, survivors=None, store=None):
        """Yield (xb, yb, weights, num_shards) per shape bucket for one
        client group, gathered from ``store`` (the round's sampled-client
        stack — built from ``client_ids`` themselves when not passed;
        callers spanning several groups pass the store once so every
        group gathers from the same round-level stack).  Dropped clients
        ride at weight 0."""
        if store is None:
            store = self._train_store(client_ids)
        for pos, xb, yb in store:
            sel = [int(i) for i in client_ids if int(i) in pos]
            if not sel:
                continue
            rows = jnp.asarray([pos[i] for i in sel], jnp.int32)
            w = np.asarray([self._client_weight(i, survivors) for i in sel],
                           np.float32)
            yield xb[rows], yb[rows], w, len(sel)

    def _test_batches(self, client_ids):
        """Memoized test-shard stacks: shards are immutable, and the
        pooled wrong/total error is order-invariant, so the ids can be
        canonicalized (sorted) and the stack built — and placed on
        device — once per participant set instead of once per key per
        generation.  Size-2 LRU (hits refresh recency): full
        participation hits every round, alternating participant sets
        keep both entries live, and partial participation — a fresh set
        each round — never pins more than two stacked copies of the
        test data."""
        key = tuple(sorted(int(i) for i in client_ids))
        cache = self._test_cache
        if key in cache:
            cache[key] = cache.pop(key)      # refresh recency (true LRU)
            self.cache_stats["test_stack_hits"] += 1
        else:
            self.cache_stats["test_stack_misses"] += 1
            if len(cache) >= 2:
                cache.pop(next(iter(cache)))  # evict least-recently-used
            with self.telemetry.span("download"):
                cache[key] = [
                    dataclasses.replace(cb, xb=self._place_test(cb.xb),
                                        yb=self._place_test(cb.yb))
                    for cb in self._group_batches(key, "test")]
        return cache[key]

    def _place_test(self, arr):
        """Device placement for the cached test stacks; ``MeshBackend``
        overrides with an explicitly mesh-replicated put so the stack is
        transferred once per participant set, not once per dispatch."""
        return jnp.asarray(arr)

    @staticmethod
    def _alive_masks(batches, survivors):
        """Per test bucket, the (S,) int32 survivor mask the masked eval
        bodies consume (all-ones when ``survivors`` is None)."""
        if survivors is None:
            return [np.ones(cb.num_shards, np.int32) for cb in batches]
        return [np.asarray([1 if int(c) in survivors else 0
                            for c in cb.client_ids], np.int32)
                for cb in batches]

    @staticmethod
    def _alive_total(batches, masks) -> int:
        """Pooled test-sample count over surviving clients — the error
        denominator matching the masked counts."""
        return int(sum(int(m.sum()) * cb.samples_per_shard
                       for cb, m in zip(batches, masks)))

    def _rates(self, counts, total, n_keys):
        """One ``jax.device_get`` per generation: the on-device
        wrong-count vector -> pooled error rates of the first ``n_keys``
        keys (the rest is mesh padding) over ``total`` surviving test
        samples.  ``total == 0`` (nobody evaluated) is pessimistic 1.0,
        never a perfect score — the same convention the strategies and
        the loop backend use.  The blocking fetch is the telemetry
        ``host_fetch`` phase — with fused eval it is where the host
        actually waits on the generation's device work."""
        if total == 0:
            return np.ones(n_keys)
        with self.telemetry.span("host_fetch"):
            wrong = np.asarray(jax.device_get(counts), np.int64)
        return wrong[:n_keys] / total

    def _group_bucket_arrays(self, keys, groups, total, pad_groups=0,
                             place=jnp.asarray, survivors=None,
                             store=None):
        """Per shape bucket of the round's sampled-client train store
        (built from the union of ``groups`` when ``store`` is not
        passed), the group-major stacked arrays the fused / sharded fill
        programs consume:
        (keys (Gp, nb) int32, xb (Gp, S, nbat, B, ...), yb, w (Gp, S)
        float32 normalized by ``total``), with the G groups padded to
        Gp = G + ``pad_groups`` and ragged groups padded to S clients —
        all padding at weight 0, so it contributes exactly nothing.
        Dropped clients (``survivors``) ride the same mechanism: they
        keep their row — the stacked shapes stay static under any
        dropout rate — but at weight 0 and with ``total`` summed over
        survivors only.  ``place`` puts each array on device (the mesh
        backend shards the leading axis here); the keys array is placed
        once and shared by every bucket."""
        out = []
        g_n = len(groups)
        keys_arr = np.zeros((g_n + pad_groups, self.api.num_blocks),
                            np.int32)
        keys_arr[:g_n] = np.stack([np.asarray(k, np.int32) for k in keys])
        karr = place(keys_arr)       # one transfer, shared by buckets
        if store is None:
            store = self._train_store([c for g in groups for c in g])
        for pos, xb_all, yb_all in store:
            entries = [[(pos[int(c)], self._client_weight(c, survivors))
                        for c in g if int(c) in pos] for g in groups]
            s_max = max((len(e) for e in entries), default=0)
            if s_max == 0:
                continue
            rows = np.zeros((g_n + pad_groups, s_max), np.int32)
            w = np.zeros((g_n + pad_groups, s_max), np.float32)
            for g, e in enumerate(entries):
                if not e:
                    continue
                rows[g, :len(e)] = [row for row, _ in e]
                # normalize exactly as fill_aggregate_stacked does (f32
                # weight vector / f64 total) — a 1-ulp difference here
                # amplifies over generations of SGD
                w[g, :len(e)] = np.asarray([wt for _, wt in e],
                                           np.float32) / total
            out.append((karr, place(xb_all[rows]), place(yb_all[rows]),
                        place(w)))
        return out

    def train_fedavg(self, params, key, client_ids, lr, survivors=None):
        """Algorithm 1 for one model == the population path at P = 1."""
        return self.train_fedavg_population([params], [key], client_ids,
                                            lr, survivors=survivors)[0]


# ---------------------------------------------------------------------------
# Vectorized backend: O(#shape-buckets) dispatches per call
# ---------------------------------------------------------------------------

class VmapBackend(StackedClientBase):
    """Vectorized execution over ``ClientBatch``-stacked shards.

    Exploits the double-sampling structure: every client in group g
    trains/evaluates the *same* choice key, so the key stays a scalar
    argument and XLA compiles exactly the selected-branch program of the
    loop backend.  (Batching the key through ``lax.switch`` would lower
    to computing all branches and selecting — a 3-4x compute blowup that
    no dispatch saving repays; measured on this repo's CNN supernet.)

    Within a dispatch the stacked client axis is consumed by
    ``lax.scan`` — per-iteration working set stays cache-sized, unlike a
    full client-axis ``vmap`` whose batched convolutions stream memory —
    with an optional inner ``vmap`` tile for evaluation
    (``RunConfig.vmap_eval_tile``), where the forward-only compute is
    cheap enough for moderate batching to pay.

    Per generation the non-fused path issues O(population) dispatches —
    constant in the number of participating clients, the axis that
    actually scales — instead of the loop backend's
    O(population x clients).  With ``cfg.fused`` (the default) the whole
    generation collapses further into one jitted program per train/eval
    call (O(1) dispatches per generation; the program still loops shape
    buckets *inside* the dispatch): the population's keys are stacked to
    (P, num_blocks) and consumed by the shared bucket bodies above, the
    master is donated off-CPU when ``master_donation_safe``, and
    evaluation returns one on-device count vector per generation instead
    of a blocking ``int(...)`` per key x tile.
    """

    name = "vmap"

    def __init__(self, api: SupernetAPI, clients: Sequence[ClientDataset],
                 cfg: RunConfig):
        super().__init__(api, clients, cfg)
        upd = client_update_fn(api, cfg.local_epochs, cfg.momentum)
        ev = eval_count_fn(api)
        mask_fn = api.trained_mask
        self.donate_master = (cfg.fused and master_donation_safe(cfg)
                              and jax.default_backend() != "cpu")

        # -- fused-generation programs (cfg.fused): one dispatch per call
        def fused_fill(master, buckets, lr):
            return cast_like(accumulate_parts(
                fill_bucket_partial(upd, mask_fn, master, keys, xb, yb,
                                    w, lr)
                for keys, xb, yb, w in buckets), master)

        def fused_uploads(master, buckets, lr):
            return tuple(train_bucket_uploads(upd, master, keys, xb, yb, lr)
                         for keys, xb, yb, _ in buckets)

        def fused_eval_shared(params, keys, shards):
            return accumulate_parts(
                eval_bucket_counts(ev, params, keys, xb, yb, alive,
                                   tile=cfg.vmap_eval_tile)
                for xb, yb, alive in shards)

        def fused_eval_paired(ps, keys, shards):
            return accumulate_parts(
                eval_paired_bucket_counts(ev, ps, keys, xb, yb, alive,
                                          tile=cfg.vmap_eval_tile)
                for xb, yb, alive in shards)

        def fused_fedavg(ps, keys, buckets, lr):
            return cast_like(accumulate_parts(
                fedavg_population_bucket(upd, ps, keys, xb, yb, wn, lr)
                for xb, yb, wn in buckets), ps)

        # every jitted program is wrapped by repro.obs.traced: each trace
        # bumps self.trace_counts[name] (the recompile counter telemetry
        # reports per round — "fused programs trace once per run" is a
        # tested invariant) and labels the program with jax.named_scope
        tc = self.trace_counts
        self._fused_fill = jax.jit(
            traced("fused_fill", tc, fused_fill),
            donate_argnums=(0,) if self.donate_master else ())
        self._fused_uploads = jax.jit(traced("fused_uploads", tc,
                                             fused_uploads))
        self._fused_eval_shared = jax.jit(traced("fused_eval_shared", tc,
                                                 fused_eval_shared))
        self._fused_eval_paired = jax.jit(traced("fused_eval_paired", tc,
                                                 fused_eval_paired))
        self._fused_fedavg = jax.jit(traced("fused_fedavg", tc,
                                            fused_fedavg))

        def scan_update(params, key, xb, yb, lr):
            # xb/yb: (L, nb, B, ...) -> stacked updated params (L, ...)
            def one(_, shard):
                return None, upd(params, key, shard[0], shard[1], lr)
            return jax.lax.scan(one, None, (xb, yb))[1]

        def scan_update_avg(params, key, xb, yb, lr, wnorm):
            # fused local SGD + weighted client average -> float32 partials
            outs = scan_update(params, key, xb, yb, lr)

            def avg(x):
                w = wnorm.reshape((-1,) + (1,) * (x.ndim - 1))
                return jnp.sum(w * x.astype(jnp.float32), axis=0)

            return jax.tree.map(avg, outs)

        def eval_tiles(params, key, xb, yb, alive):
            # xb/yb: (T, tile, nb, B, ...), alive (T, tile) int32 survivor
            # mask -> total error count over surviving clients
            tile_ev = jax.vmap(ev, in_axes=(None, None, 0, 0))

            def one(acc, shard):
                return acc + jnp.sum(shard[2] * tile_ev(params, key,
                                                        shard[0],
                                                        shard[1])), None
            return jax.lax.scan(one, jnp.zeros((), jnp.int32),
                                (xb, yb, alive))[0]

        self._scan_update = jax.jit(traced("scan_update", tc, scan_update))
        self._scan_update_avg = jax.jit(traced("scan_update_avg", tc,
                                               scan_update_avg))
        self._eval_tiles = jax.jit(traced("eval_tiles", tc, eval_tiles))

    # -- protocol -----------------------------------------------------------

    def train_fill(self, master, keys, groups, lr, survivors=None):
        if self.cfg.fused:
            return self._train_fill_fused(master, keys, groups, lr,
                                          survivors)
        chunks = []
        # one sampled-client stack for the whole generation — every group
        # gathers from it, so the LRU sees a single round-level key
        all_ids = [int(c) for g in groups for c in g]
        store = self._train_store(all_ids) if all_ids else None
        for key, group in zip(keys, groups):
            if len(group) == 0:
                continue
            if survivors is not None and \
                    not any(int(c) in survivors for c in group):
                continue    # fully-dropped group: its weight-0 rows would
                # contribute exactly nothing — skip the training dispatch
            jkey = np.asarray(key, np.int32)
            for xb, yb, w, n in self._group_train_gather(group, survivors,
                                                         store=store):
                out = self._scan_update(master, jkey, xb, yb, lr)
                self.dispatches += 1
                chunks.append((out, np.tile(jkey, (n, 1)), w))
        if not chunks or not any(np.any(w) for _, _, w in chunks):
            return master              # nobody survived: master untouched
        # per-group stacked uploads feed the batched fill directly (one
        # dispatch per chunk; concatenating first would duplicate every
        # upload on device just to save the partial-sum adds)
        master = fill_aggregate_stacked(master, chunks,
                                        mask_fn=self.api.trained_mask,
                                        backend=self.cfg.aggregate_backend)
        self.dispatches += len(chunks)
        return master

    def _train_fill_fused(self, master, keys, groups, lr, survivors=None):
        groups = [np.asarray(g) for g in groups]
        total = self._survivor_total([c for g in groups for c in g],
                                     survivors)
        if total == 0.0:
            return master
        buckets = tuple(self._group_bucket_arrays(keys, groups, total,
                                                  survivors=survivors))
        if not buckets:
            return master
        lr = jnp.float32(lr)
        if self.cfg.aggregate_backend == "pallas":
            # partial fusion: one program for the whole population's
            # local SGD, then Algorithm 3 through the Pallas kernel
            outs = self._fused_uploads(master, buckets, lr)
            self.dispatches += 1
            chunks = []
            for (keys_a, _, _, w), out in zip(buckets, outs):
                gp, s = np.asarray(w).shape
                flat = jax.tree.map(
                    lambda x: x.reshape((gp * s,) + x.shape[2:]), out)
                chunks.append((flat,
                               np.repeat(np.asarray(keys_a), s, axis=0),
                               np.asarray(w).reshape(-1)))
            master = fill_aggregate_stacked(master, chunks,
                                            mask_fn=self.api.trained_mask,
                                            backend="pallas", total=1.0)
            self.dispatches += len(chunks)
            return master
        # donated master: the caller's buffers are reused for the update
        out = self._fused_fill(master, buckets, lr)
        self.dispatches += 1
        return out

    def _fedavg_from_batches(self, params, jkey, batches, total, lr):
        acc = None
        for xb, yb, w, _ in batches:
            part = self._scan_update_avg(params, jkey, xb, yb,
                                         lr, w / total)
            self.dispatches += 1
            acc = part if acc is None else jax.tree.map(jnp.add, acc, part)
        return jax.tree.map(lambda a, p: a.astype(p.dtype), acc, params)

    def train_fedavg_population(self, params_list, keys, client_ids, lr,
                                survivors=None):
        # gather the participants' train shards once for every individual
        batches = list(self._group_train_gather(client_ids, survivors))
        total = self._survivor_total(client_ids, survivors)
        if total == 0.0:               # nobody survived: models untouched
            return list(params_list)
        if self.cfg.fused:
            if not params_list:
                return []
            ps = jax.tree.map(lambda *xs: jnp.stack(xs), *params_list)
            karr = jnp.asarray(np.stack([np.asarray(k, np.int32)
                                         for k in keys]))
            buckets = tuple((xb, yb, jnp.asarray(w / total))
                            for xb, yb, w, _ in batches)
            out = self._fused_fedavg(ps, karr, buckets, jnp.float32(lr))
            self.dispatches += 1
            return [jax.tree.map(lambda x: x[i], out)
                    for i in range(len(params_list))]
        return [self._fedavg_from_batches(p, np.asarray(k, np.int32),
                                          batches, total, lr)
                for p, k in zip(params_list, keys)]

    def _eval_one(self, params, jkey, batches, masks, total):
        if total == 0:
            return 1.0                 # nobody evaluated: pessimistic
        wrong = 0
        for batch, alive in zip(batches, masks):
            m = batch.num_shards
            tile = max(1, min(self.cfg.vmap_eval_tile, m))
            full = (m // tile) * tile
            tail = batch.xb.shape[1:]
            if full:
                wrong += int(self._eval_tiles(
                    params, jkey,
                    batch.xb[:full].reshape((full // tile, tile) + tail),
                    batch.yb[:full].reshape((full // tile, tile)
                                            + batch.yb.shape[1:]),
                    alive[:full].reshape((full // tile, tile))))
                self.dispatches += 1
            if m > full:
                wrong += int(self._eval_tiles(params, jkey,
                                              batch.xb[None, full:],
                                              batch.yb[None, full:],
                                              alive[None, full:]))
                self.dispatches += 1
        return wrong / total

    def eval_shared(self, params, keys, client_ids, survivors=None):
        batches = self._test_batches(client_ids)
        masks = self._alive_masks(batches, survivors)
        total = self._alive_total(batches, masks)
        if self.cfg.fused:
            karr = jnp.asarray(np.stack([np.asarray(k, np.int32)
                                         for k in keys]))
            counts = self._fused_eval_shared(
                params, karr, tuple((cb.xb, cb.yb, m)
                                    for cb, m in zip(batches, masks)))
            self.dispatches += 1
            return self._rates(counts, total, len(keys))
        return np.asarray([self._eval_one(params, np.asarray(k, np.int32),
                                          batches, masks, total)
                           for k in keys])

    def eval_paired(self, params_list, keys, client_ids, survivors=None):
        batches = self._test_batches(client_ids)
        masks = self._alive_masks(batches, survivors)
        total = self._alive_total(batches, masks)
        if self.cfg.fused:
            ps = jax.tree.map(lambda *xs: jnp.stack(xs), *params_list)
            karr = jnp.asarray(np.stack([np.asarray(k, np.int32)
                                         for k in keys]))
            counts = self._fused_eval_paired(
                ps, karr, tuple((cb.xb, cb.yb, m)
                                for cb, m in zip(batches, masks)))
            self.dispatches += 1
            return self._rates(counts, total, len(keys))
        return np.asarray([self._eval_one(p, np.asarray(k, np.int32),
                                          batches, masks, total)
                           for p, k in zip(params_list, keys)])



BACKENDS = {"loop": LoopBackend, "vmap": VmapBackend}
BACKEND_NAMES = ("loop", "mesh", "vmap")


def make_backend(name: str, api: SupernetAPI,
                 clients: Sequence[ClientDataset],
                 cfg: RunConfig) -> ExecutionBackend:
    """Build the execution backend ``name`` ('loop' | 'vmap' | 'mesh').

    Called by ``FedEngine.__init__`` — i.e. at configuration time, so an
    unknown name fails before any round runs.  ``MeshBackend`` lives in
    ``repro.engine.mesh_backend`` and registers itself into ``BACKENDS``
    when that module is imported (``repro.engine.__init__`` does so
    eagerly; no jax device/mesh state is touched until instantiation)."""
    try:
        cls = BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown execution backend {name!r}; "
            f"available: {list(BACKEND_NAMES)}") from None
    return cls(api, clients, cfg)
