"""Pluggable client-execution backends for the federated engine.

A backend answers four questions for a strategy — *how* to run local SGD
and evaluation, never *what* to run (sampling, accounting and selection
live in the strategies / engine, so every backend sees the same inputs):

  * ``train_fill``   — train keys[i]'s sub-model on client group i from a
    shared master and fill-aggregate the uploads (Algorithm 3/4).
  * ``train_fedavg`` / ``train_fedavg_population`` — train one (or each)
    standalone model on every listed client and FedAvg per model
    (Algorithm 1 / the offline baseline).
  * ``eval_shared`` / ``eval_paired`` — weighted test error of K keys on a
    shared master, or of K (params, key) pairs.

``LoopBackend`` is the reference: one jitted dispatch per
(individual, client) pair, exactly the pre-engine semantics.
``VmapBackend`` stacks each same-shape client group into a ``ClientBatch``
and runs all population x client updates — and all 2N x participants
evaluations — in O(population) jitted dispatches per generation,
constant in the number of participating clients.  ``MeshBackend``
(``repro.engine.mesh_backend``) additionally shards the population axis
of those stacks over a jax device mesh, for O(population / devices)
dispatches per generation.  All backends count ``dispatches`` so tests
and benchmarks can assert those claims instead of trusting them.

Every backend routes Algorithm 3 through ``RunConfig.aggregate_backend``
identically: ``"xla"`` is the jnp reference, ``"pallas"`` the
``repro.kernels.fill_aggregate`` TPU kernel (interpret-mode off-TPU).
Unknown values are rejected by ``RunConfig`` at construction time.

Payload codecs never appear in this module: when
``RunConfig.uplink_codec`` / ``downlink_codec`` select a lossy codec,
``FedEngine`` wraps whichever backend it built in
``repro.comm.backend.CodecBackend``, which applies encode->decode around
these train/eval entry points uniformly — so the dispatch math here (and
in ``mesh_backend``) stays codec-free and every backend sees identical
compressed inputs.
"""
from __future__ import annotations

from typing import Any, List, Protocol, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import fedavg, fill_aggregate, \
    fill_aggregate_stacked
from repro.core.federated import client_update_fn, eval_count_fn, \
    make_client_update, make_evaluator, weighted_test_error
from repro.core.supernet import SupernetAPI
from repro.data.pipeline import ClientBatch, ClientDataset, shape_buckets
from repro.engine.types import RunConfig

Params = Any


class ExecutionBackend(Protocol):
    """The dispatch contract every backend implements.

    ``dispatches`` counts jitted device dispatches issued so far (the
    scaling claims in docs/architecture.md are asserted against it).
    All ``keys`` are (num_blocks,) int32 choice keys; ``client_ids`` /
    ``groups`` index into the backend's client list; ``lr`` is the
    round's learning rate.  Returned parameters are full pytrees;
    ``eval_*`` return (len(keys),) float64 weighted test-error rates in
    [0, 1]."""

    name: str
    dispatches: int

    def train_fill(self, master: Params, keys: Sequence[np.ndarray],
                   groups: Sequence[np.ndarray], lr: float) -> Params:
        """Train keys[g] on client group g from the shared master and
        fill-aggregate the uploads into the new master (Algorithm 3/4)."""
        ...

    def train_fedavg(self, params: Params, key: np.ndarray,
                     client_ids: np.ndarray, lr: float) -> Params:
        """One FedAvg round of ``key``'s standalone model over every
        listed client (Algorithm 1)."""
        ...

    def train_fedavg_population(self, params_list: Sequence[Params],
                                keys: Sequence[np.ndarray],
                                client_ids: np.ndarray,
                                lr: float) -> List[Params]:
        """``train_fedavg`` for each (params, key) pair — every client
        trains every individual (the offline baseline)."""
        ...

    def eval_shared(self, params: Params, keys: Sequence[np.ndarray],
                    client_ids: np.ndarray) -> np.ndarray:
        """Weighted test-error rate of every key on one shared master."""
        ...

    def eval_paired(self, params_list: Sequence[Params],
                    keys: Sequence[np.ndarray],
                    client_ids: np.ndarray) -> np.ndarray:
        """Weighted test-error rate of every (params, key) pair."""
        ...


# ---------------------------------------------------------------------------
# Reference backend: one dispatch per pair
# ---------------------------------------------------------------------------

class LoopBackend:
    """Reference execution: one jitted dispatch per (individual, client)
    pair — exactly the pre-engine (per-pair Python loop) semantics that
    the batched backends are tested against.  Algorithm 3 routes through
    ``fill_aggregate(backend=cfg.aggregate_backend)``."""

    name = "loop"

    def __init__(self, api: SupernetAPI, clients: Sequence[ClientDataset],
                 cfg: RunConfig):
        self.api = api
        self.clients = clients
        self.cfg = cfg
        self.update = make_client_update(api, cfg.local_epochs, cfg.momentum)
        self.evaluate = make_evaluator(api)
        self.dispatches = 0

    def train_fill(self, master, keys, groups, lr):
        uploads = []
        for key, group in zip(keys, groups):
            jkey = np.asarray(key, np.int32)
            for cid in group:
                c = self.clients[int(cid)]
                xb, yb = c.train
                p_k = self.update(master, jkey, xb, yb, lr)
                self.dispatches += 1
                uploads.append((p_k, self.api.trained_mask(p_k, key),
                                c.weight))
        if not uploads:
            return master
        self.dispatches += 1
        return fill_aggregate(master, uploads,
                              backend=self.cfg.aggregate_backend)

    def train_fedavg(self, params, key, client_ids, lr):
        jkey = np.asarray(key, np.int32)
        uploads = []
        for cid in client_ids:
            c = self.clients[int(cid)]
            xb, yb = c.train
            uploads.append((self.update(params, jkey, xb, yb, lr), c.weight))
            self.dispatches += 1
        self.dispatches += 1
        return fedavg(uploads)

    def train_fedavg_population(self, params_list, keys, client_ids, lr):
        return [self.train_fedavg(p, k, client_ids, lr)
                for p, k in zip(params_list, keys)]

    def eval_shared(self, params, keys, client_ids):
        part = [self.clients[int(i)] for i in client_ids]
        errs = []
        for k in keys:
            errs.append(weighted_test_error(
                self.evaluate, params, np.asarray(k, np.int32), part))
            self.dispatches += len(part)
        return np.asarray(errs)

    def eval_paired(self, params_list, keys, client_ids):
        part = [self.clients[int(i)] for i in client_ids]
        errs = []
        for p, k in zip(params_list, keys):
            errs.append(weighted_test_error(
                self.evaluate, p, np.asarray(k, np.int32), part))
            self.dispatches += len(part)
        return np.asarray(errs)


# ---------------------------------------------------------------------------
# Shared stacking/caching for the batched (vmap, mesh) backends
# ---------------------------------------------------------------------------

class StackedClientBase:
    """Host-side stacking, bucketing and caching shared by the batched
    execution backends (``VmapBackend``, ``MeshBackend``): a
    device-resident stacked train-shard store, per-group gathers from it,
    and a memoized stacked test set per participant set.  Subclasses
    implement the ``ExecutionBackend`` protocol on top."""

    def __init__(self, api: SupernetAPI, clients: Sequence[ClientDataset],
                 cfg: RunConfig):
        self.api = api
        self.clients = clients
        self.cfg = cfg
        self._test_cache = {}
        self._train_store_cache = None
        self.dispatches = 0

    def _stack(self, client_ids, split):
        return ClientBatch.stack([self.clients[int(i)] for i in client_ids],
                                 split=split)

    def _group_batches(self, client_ids, split):
        """Yield ClientBatches for one client group, bucketed by shape."""
        shapes = [(self.clients[int(i)].train if split == "train"
                   else self.clients[int(i)].test)[0].shape
                  for i in client_ids]
        for idxs in shape_buckets(shapes):
            yield self._stack([client_ids[i] for i in idxs], split)

    def _train_store(self):
        """Device-resident stacked train shards for ALL clients, built
        once (shards are immutable): [(cid -> row, xb, yb)] per shape
        bucket.  Groups are then gathered device-side each generation
        instead of host-restacking and re-transferring the same data."""
        if self._train_store_cache is None:
            shapes = [c.train[0].shape for c in self.clients]
            store = []
            for idxs in shape_buckets(shapes):
                xb = jnp.stack([jnp.asarray(self.clients[i].train[0])
                                for i in idxs])
                yb = jnp.stack([jnp.asarray(self.clients[i].train[1])
                                for i in idxs])
                store.append(({cid: row for row, cid in enumerate(idxs)},
                              xb, yb))
            self._train_store_cache = store
        return self._train_store_cache

    def _group_train_gather(self, client_ids):
        """Yield (xb, yb, weights, num_shards) per shape bucket for one
        client group, gathered from the resident store."""
        for pos, xb, yb in self._train_store():
            sel = [int(i) for i in client_ids if int(i) in pos]
            if not sel:
                continue
            rows = jnp.asarray([pos[i] for i in sel], jnp.int32)
            w = np.asarray([self.clients[i].weight for i in sel],
                           np.float32)
            yield xb[rows], yb[rows], w, len(sel)

    def _test_batches(self, client_ids):
        """Memoized test-shard stacks: shards are immutable, and the
        pooled wrong/total error is order-invariant, so the ids can be
        canonicalized (sorted) and the host-side np.stack done once per
        participant set instead of once per key per generation.  Size-2
        (current + previous set): full participation hits every round,
        while partial participation — a fresh set each round — never
        pins more than two stacked copies of the test data."""
        key = tuple(sorted(int(i) for i in client_ids))
        if key not in self._test_cache:
            if len(self._test_cache) >= 2:
                self._test_cache.pop(next(iter(self._test_cache)))
            self._test_cache[key] = list(self._group_batches(key, "test"))
        return self._test_cache[key]

    def train_fedavg(self, params, key, client_ids, lr):
        """Algorithm 1 for one model == the population path at P = 1."""
        return self.train_fedavg_population([params], [key],
                                            client_ids, lr)[0]


# ---------------------------------------------------------------------------
# Vectorized backend: O(#shape-buckets) dispatches per call
# ---------------------------------------------------------------------------

class VmapBackend(StackedClientBase):
    """Vectorized execution over ``ClientBatch``-stacked shards.

    Exploits the double-sampling structure: every client in group g
    trains/evaluates the *same* choice key, so the key stays a scalar
    argument and XLA compiles exactly the selected-branch program of the
    loop backend.  (Batching the key through ``lax.switch`` would lower
    to computing all branches and selecting — a 3-4x compute blowup that
    no dispatch saving repays; measured on this repo's CNN supernet.)

    Within a dispatch the stacked client axis is consumed by
    ``lax.scan`` — per-iteration working set stays cache-sized, unlike a
    full client-axis ``vmap`` whose batched convolutions stream memory —
    with an optional inner ``vmap`` tile for evaluation
    (``RunConfig.vmap_eval_tile``), where the forward-only compute is
    cheap enough for moderate batching to pay.

    Per generation this issues O(population) dispatches — constant in
    the number of participating clients, the axis that actually scales —
    instead of the loop backend's O(population x clients).
    """

    name = "vmap"

    def __init__(self, api: SupernetAPI, clients: Sequence[ClientDataset],
                 cfg: RunConfig):
        super().__init__(api, clients, cfg)
        upd = client_update_fn(api, cfg.local_epochs, cfg.momentum)
        ev = eval_count_fn(api)

        def scan_update(params, key, xb, yb, lr):
            # xb/yb: (L, nb, B, ...) -> stacked updated params (L, ...)
            def one(_, shard):
                return None, upd(params, key, shard[0], shard[1], lr)
            return jax.lax.scan(one, None, (xb, yb))[1]

        def scan_update_avg(params, key, xb, yb, lr, wnorm):
            # fused local SGD + weighted client average -> float32 partials
            outs = scan_update(params, key, xb, yb, lr)

            def avg(x):
                w = wnorm.reshape((-1,) + (1,) * (x.ndim - 1))
                return jnp.sum(w * x.astype(jnp.float32), axis=0)

            return jax.tree.map(avg, outs)

        def eval_tiles(params, key, xb, yb):
            # xb/yb: (T, tile, nb, B, ...) -> total error count
            tile_ev = jax.vmap(ev, in_axes=(None, None, 0, 0))

            def one(acc, shard):
                return acc + jnp.sum(tile_ev(params, key,
                                             shard[0], shard[1])), None
            return jax.lax.scan(one, jnp.zeros((), jnp.int32),
                                (xb, yb))[0]

        self._scan_update = jax.jit(scan_update)
        self._scan_update_avg = jax.jit(scan_update_avg)
        self._eval_tiles = jax.jit(eval_tiles)

    # -- protocol -----------------------------------------------------------

    def train_fill(self, master, keys, groups, lr):
        chunks = []
        for key, group in zip(keys, groups):
            if len(group) == 0:
                continue
            jkey = np.asarray(key, np.int32)
            for xb, yb, w, n in self._group_train_gather(group):
                out = self._scan_update(master, jkey, xb, yb, lr)
                self.dispatches += 1
                chunks.append((out, np.tile(jkey, (n, 1)), w))
        if not chunks:
            return master
        # per-group stacked uploads feed the batched fill directly (one
        # dispatch per chunk; concatenating first would duplicate every
        # upload on device just to save the partial-sum adds)
        master = fill_aggregate_stacked(master, chunks,
                                        mask_fn=self.api.trained_mask,
                                        backend=self.cfg.aggregate_backend)
        self.dispatches += len(chunks)
        return master

    def _fedavg_from_batches(self, params, jkey, batches, total, lr):
        acc = None
        for xb, yb, w, _ in batches:
            part = self._scan_update_avg(params, jkey, xb, yb,
                                         lr, w / total)
            self.dispatches += 1
            acc = part if acc is None else jax.tree.map(jnp.add, acc, part)
        return jax.tree.map(lambda a, p: a.astype(p.dtype), acc, params)

    def train_fedavg_population(self, params_list, keys, client_ids, lr):
        # gather the participants' train shards once for every individual
        batches = list(self._group_train_gather(client_ids))
        total = float(sum(self.clients[int(i)].weight for i in client_ids))
        return [self._fedavg_from_batches(p, np.asarray(k, np.int32),
                                          batches, total, lr)
                for p, k in zip(params_list, keys)]

    def _eval_one(self, params, jkey, batches):
        wrong = total = 0
        for batch in batches:
            m = batch.num_shards
            tile = max(1, min(self.cfg.vmap_eval_tile, m))
            full = (m // tile) * tile
            tail = batch.xb.shape[1:]
            if full:
                wrong += int(self._eval_tiles(
                    params, jkey,
                    batch.xb[:full].reshape((full // tile, tile) + tail),
                    batch.yb[:full].reshape((full // tile, tile)
                                            + batch.yb.shape[1:])))
                self.dispatches += 1
            if m > full:
                wrong += int(self._eval_tiles(params, jkey,
                                              batch.xb[None, full:],
                                              batch.yb[None, full:]))
                self.dispatches += 1
            total += m * batch.samples_per_shard
        return wrong / max(total, 1)

    def eval_shared(self, params, keys, client_ids):
        batches = self._test_batches(client_ids)
        return np.asarray([self._eval_one(params, np.asarray(k, np.int32),
                                          batches) for k in keys])

    def eval_paired(self, params_list, keys, client_ids):
        batches = self._test_batches(client_ids)
        return np.asarray([self._eval_one(p, np.asarray(k, np.int32),
                                          batches)
                           for p, k in zip(params_list, keys)])


BACKENDS = {"loop": LoopBackend, "vmap": VmapBackend}
BACKEND_NAMES = ("loop", "mesh", "vmap")


def make_backend(name: str, api: SupernetAPI,
                 clients: Sequence[ClientDataset],
                 cfg: RunConfig) -> ExecutionBackend:
    """Build the execution backend ``name`` ('loop' | 'vmap' | 'mesh').

    Called by ``FedEngine.__init__`` — i.e. at configuration time, so an
    unknown name fails before any round runs.  ``MeshBackend`` lives in
    ``repro.engine.mesh_backend`` and registers itself into ``BACKENDS``
    when that module is imported (``repro.engine.__init__`` does so
    eagerly; no jax device/mesh state is touched until instantiation)."""
    try:
        cls = BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown execution backend {name!r}; "
            f"available: {list(BACKEND_NAMES)}") from None
    return cls(api, clients, cfg)
