"""Typed state shared by every federated NAS runtime.

``CommStats`` (moved here from ``repro.core.rt_enas``) accounts both the
training-phase traffic (sub-model downloads/uploads, Algorithm 3/4) and the
evaluation-phase traffic the paper's Section IV.G comparison needs: the 2N
choice-key downloads before fitness evaluation and the per-client
error-count uploads afterwards.  ``RoundReport`` is the typed per-round
history record every strategy produces; ``history_dict`` flattens a list of
reports into the legacy dict-of-lists layout that ``rt_enas.run`` /
``offline_enas.run`` used to return.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

BYTES_PER_PARAM = 4        # float32 payloads
ERROR_COUNT_BYTES = 4      # one int32 error count per evaluated sub-model


@dataclasses.dataclass
class RunConfig:
    population: int = 10
    generations: int = 500
    participation: float = 1.0          # C in the paper
    lr0: float = 0.1
    lr_decay: float = 0.995
    momentum: float = 0.5
    local_epochs: int = 1
    crossover: float = 0.9
    mutation: float = 0.1
    seed: int = 0
    aggregate_backend: str = "xla"      # 'pallas' routes Algorithm 3 to the kernel
    backend: str = "loop"               # execution backend: 'loop' | 'vmap'
    vmap_eval_tile: int = 32            # clients vmapped per eval scan step


@dataclasses.dataclass
class CommStats:
    down_bytes: float = 0.0
    up_bytes: float = 0.0
    client_train_passes: int = 0
    eval_down_bytes: float = 0.0        # subset of down_bytes (fitness phase)
    eval_up_bytes: float = 0.0          # subset of up_bytes (fitness phase)

    def add_download(self, params: int, copies: int = 1):
        self.down_bytes += BYTES_PER_PARAM * params * copies

    def add_upload(self, params: int, copies: int = 1):
        self.up_bytes += BYTES_PER_PARAM * params * copies

    def add_eval_download_bytes(self, nbytes: float, copies: int = 1):
        self.down_bytes += nbytes * copies
        self.eval_down_bytes += nbytes * copies

    def add_eval_upload_bytes(self, nbytes: float, copies: int = 1):
        self.up_bytes += nbytes * copies
        self.eval_up_bytes += nbytes * copies


@dataclasses.dataclass
class RoundReport:
    """One federated round (== one NSGA-II generation for the NAS
    strategies).  Search fields a strategy does not produce stay ``None``
    and are dropped from the legacy history dict."""
    gen: int
    objs: Optional[np.ndarray] = None          # (2N, 2) [err, flops]
    parent_keys: Optional[List[np.ndarray]] = None
    best_err: Optional[float] = None
    best_key: Optional[np.ndarray] = None
    knee_err: Optional[float] = None
    knee_key: Optional[np.ndarray] = None
    # stamped by the engine after the strategy returns:
    down_gb: float = 0.0
    up_gb: float = 0.0
    train_passes: int = 0
    wall_s: float = 0.0


HISTORY_FIELDS = ("gen", "objs", "parent_keys", "best_err", "knee_err",
                  "best_key", "knee_key", "down_gb", "up_gb",
                  "train_passes", "wall_s")


def append_report(hist: Dict[str, list], report: RoundReport) -> None:
    """Append one round to a legacy dict-of-lists history in place
    (fields the strategy does not produce are dropped)."""
    for f in HISTORY_FIELDS:
        v = getattr(report, f)
        if v is not None:
            hist.setdefault(f, []).append(v)


def history_dict(reports: List[RoundReport]) -> Dict[str, list]:
    """Legacy dict-of-lists view (keys with all-None values are dropped)."""
    out: Dict[str, list] = {}
    for r in reports:
        append_report(out, r)
    return out


@dataclasses.dataclass
class EngineResult:
    reports: List[RoundReport]
    stats: CommStats
    extras: Dict

    def history(self) -> Dict:
        out = history_dict(self.reports)
        out.update(self.extras)
        out["stats"] = self.stats
        return out
