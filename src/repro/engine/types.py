"""Typed state shared by every federated NAS runtime.

``CommStats`` (moved here from ``repro.core.rt_enas``) accounts both the
training-phase traffic (sub-model downloads/uploads, Algorithm 3/4) and the
evaluation-phase traffic the paper's Section IV.G comparison needs: the 2N
choice-key downloads before fitness evaluation and the per-client
error-count uploads afterwards.  Every byte is counted twice: once as
fp32-*logical* bytes (``BYTES_PER_PARAM`` per parameter — the paper's
Section IV.G unit, codec-independent) and once as *wire* bytes (what the
``RunConfig.uplink_codec`` / ``downlink_codec`` payload codecs actually
put on the network — ``repro.comm``).  ``RoundReport`` is the typed
per-round history record every strategy produces; ``history_dict``
flattens a list of reports into the legacy dict-of-lists layout that
``rt_enas.run`` / ``offline_enas.run`` used to return.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.obs import TelemetryConfig, TelemetryResult

BYTES_PER_PARAM = 4        # float32 logical payloads
ERROR_COUNT_BYTES = 4      # one int32 error count per evaluated sub-model


AGGREGATE_BACKENDS = ("xla", "pallas")
# execution backend names live in backends.BACKEND_NAMES (single source)


@dataclasses.dataclass
class ClientSimConfig:
    """Real-time client availability / heterogeneity simulation.

    The paper's headline claim is *real-time* federated NAS: mobile
    clients come and go, and double sampling plus weight inheritance
    keep the search stable despite that.  This config models the three
    failure modes the FedNAS literature singles out, all drawn from a
    dedicated RNG stream (``seed``) so the *search* trajectory
    (participant sampling, offspring variation) never shifts when the
    simulation knobs change:

      * ``availability`` — probability that a sampled client actually
        checks in this round (it never receives a download otherwise).
        ``availability_trace`` optionally gives one probability per
        client (device classes: phones vs. plugged-in tablets),
        overriding the scalar.  ``availability_dist`` instead draws each
        client's per-round check-in probability from a compact
        distribution spec — ``("bernoulli", q)`` (a ``q`` fraction of
        clients are always on, the rest never), ``("uniform", lo, hi)``
        or ``("beta", a, b)`` — keyed by a counter-based per-client
        stream, so a 10^6-client fleet costs O(1) state instead of a
        length-``num_clients`` trace array; mutually exclusive with
        ``availability_trace``.
      * ``dropout`` — probability that a checked-in client fails
        *after* its downloads but *before* any upload: its local
        training is lost (excluded from aggregation, no upload bytes),
        it reports no evaluation counts, and every byte pushed to it
        this round lands on the ``CommStats`` wasted ledger.
      * ``straggler_fraction`` / ``straggler_slowdown`` /
        ``round_deadline`` — a fixed ``straggler_fraction`` of clients
        run ``straggler_slowdown``× slower; per round each checked-in
        client finishes at ``speed × U(0.8, 1.2)`` (1.0 = a nominal
        round) and clients past ``round_deadline`` miss the round's
        aggregation — same consequence as ``dropout``.  ``None``
        disables the deadline.

    The defaults simulate nothing: ``ClientSimConfig()`` reproduces the
    fully-synchronous trajectories bit for bit (no sim RNG is even
    drawn), which is asserted by ``tests/test_availability.py``.
    """
    availability: float = 1.0
    availability_trace: Optional[tuple] = None   # per-client P(available)
    availability_dist: Optional[tuple] = None    # compact per-client spec
    dropout: float = 0.0
    straggler_fraction: float = 0.0
    straggler_slowdown: float = 1.0
    round_deadline: Optional[float] = None
    seed: int = 0

    def __post_init__(self):
        if not 0.0 < self.availability <= 1.0:
            raise ValueError(
                f"availability must be in (0, 1], got {self.availability}")
        if self.availability_trace is not None:
            trace = tuple(float(p) for p in self.availability_trace)
            if not all(0.0 <= p <= 1.0 for p in trace):
                raise ValueError("availability_trace entries must be in "
                                 f"[0, 1], got {trace}")
            self.availability_trace = trace
        if self.availability_dist is not None:
            if self.availability_trace is not None:
                raise ValueError("availability_dist and availability_trace "
                                 "are mutually exclusive — pick one")
            dist = tuple(self.availability_dist)
            if not dist or not isinstance(dist[0], str):
                raise ValueError(
                    "availability_dist must be ('bernoulli', q) | "
                    f"('uniform', lo, hi) | ('beta', a, b), got {dist!r}")
            name, params = dist[0], tuple(float(p) for p in dist[1:])
            if name == "bernoulli":
                if len(params) != 1 or not 0.0 <= params[0] <= 1.0:
                    raise ValueError("('bernoulli', q) needs one q in "
                                     f"[0, 1], got {dist!r}")
            elif name == "uniform":
                if (len(params) != 2
                        or not 0.0 <= params[0] <= params[1] <= 1.0):
                    raise ValueError("('uniform', lo, hi) needs "
                                     f"0 <= lo <= hi <= 1, got {dist!r}")
            elif name == "beta":
                if len(params) != 2 or min(params) <= 0.0:
                    raise ValueError("('beta', a, b) needs a, b > 0, "
                                     f"got {dist!r}")
            else:
                raise ValueError(
                    f"unknown availability_dist {name!r}: expected "
                    "'bernoulli', 'uniform' or 'beta'")
            self.availability_dist = (name,) + params
        if not 0.0 <= self.dropout <= 1.0:
            raise ValueError(
                f"dropout must be in [0, 1], got {self.dropout}")
        if not 0.0 <= self.straggler_fraction <= 1.0:
            raise ValueError(f"straggler_fraction must be in [0, 1], "
                             f"got {self.straggler_fraction}")
        if self.straggler_slowdown < 1.0:
            raise ValueError(f"straggler_slowdown must be >= 1, "
                             f"got {self.straggler_slowdown}")
        if self.round_deadline is not None and self.round_deadline <= 0:
            raise ValueError(f"round_deadline must be > 0 or None, "
                             f"got {self.round_deadline}")
        if self.straggler_fraction > 0.0 and self.round_deadline is None:
            raise ValueError(
                "straggler_fraction > 0 does nothing without a "
                "round_deadline (stragglers only miss rounds against a "
                "deadline) — set round_deadline or drop the stragglers")

    @property
    def is_active(self) -> bool:
        """Whether any knob deviates from the fully-synchronous world.
        Inactive configs take the exact legacy engine path."""
        return (self.availability < 1.0
                or self.availability_trace is not None
                or self.availability_dist is not None
                or self.dropout > 0.0
                or self.round_deadline is not None)


@dataclasses.dataclass
class RunConfig:
    """Every knob of a federated NAS run, validated at construction.

    Search / schedule:
      * ``population`` — N, individuals per generation (Algorithm 4).
      * ``generations`` — rounds to run (one NSGA-II generation == one
        federated communication round).
      * ``participation`` — C in the paper: fraction of clients sampled
        each round (m = round(C * K) participants).
      * ``lr0`` / ``lr_decay`` — client SGD learning rate, decayed as
        ``lr0 * lr_decay**(gen - 1)`` per round.
      * ``momentum`` / ``local_epochs`` — client-side SGD momentum and
        number of local passes E over the client shard per round.
      * ``crossover`` / ``mutation`` — per-offspring probabilities of the
        two variation operators (Algorithm 2).
      * ``seed`` — seeds both participant/group sampling and model init.

    Execution:
      * ``aggregate_backend`` — how Algorithm 3 (fill-aggregation) is
        computed: ``"xla"`` (jnp reference) or ``"pallas"`` (the
        ``repro.kernels.fill_aggregate`` TPU kernel; interpret-mode —
        i.e. XLA-orchestrated, Python-executed — off-TPU).  Honored by
        every execution backend; unknown values raise here, at config
        time.
      * ``backend`` — client-execution backend: ``"loop"`` (reference,
        one dispatch per (individual, client) pair), ``"vmap"``
        (ClientBatch-stacked, O(population) dispatches/gen) or ``"mesh"``
        (population axis sharded over a jax device mesh,
        O(population / devices) dispatches/gen).  Validated when the
        engine builds the backend.
      * ``vmap_eval_tile`` — clients evaluated per inner vmap tile in
        the batched backends' forward-only eval paths (>= 1).  Tiling
        never changes results: error counts are integers, so any
        client-axis batching yields bitwise-identical totals.
      * ``fused`` — run each generation of the batched backends
        (``"vmap"``, ``"mesh"``) as a constant number of jitted
        dispatches: one program per ``train_fill`` (local-SGD scan +
        per-group weighting + the Algorithm 3 partial sums, master
        passed with ``donate_argnums`` off-CPU so the per-generation
        master update reuses its buffers) and one per evaluation call
        (all stacked keys -> one on-device wrong-count vector, fetched
        with a single ``jax.device_get``).  Defaults to True — the
        measured-faster path (see ``BENCH_engine.json``); ``False``
        restores the per-bucket/per-key dispatch pattern.  Ignored by
        the ``"loop"`` reference backend.

    Communication (``repro.comm``; validated here like
    ``aggregate_backend``):
      * ``uplink_codec`` — payload codec for client->server transfers
        (trained sub-model uploads).  ``"none"`` (fp32), ``"cast"`` /
        ``"cast:fp16"`` (16-bit float), ``"int8"`` / ``"int8:pallas"``
        (per-tensor symmetric quantization), ``"topk"`` /
        ``"topk:<ratio>"`` (magnitude sparsification).  Lossy uplink
        codecs compose with server-side error feedback on the
        persistent-master paths.
      * ``downlink_codec`` — same spec grammar for server->client
        transfers (master broadcasts / sub-model downloads).

    Client availability (``client_sim``):
      * a ``ClientSimConfig`` (also accepted as a plain dict) modeling
        real-time device behavior — per-round availability, post-download
        dropout, stragglers against a round deadline.  The default
        simulates nothing and reproduces the synchronous trajectories
        bit for bit; see the ``ClientSimConfig`` docstring.

    Observability (``telemetry``):
      * a ``repro.obs.TelemetryConfig`` (also accepted as a plain dict,
        or ``True`` for all defaults) turning on phase spans, recompile
        counters, resource gauges and structured per-round events on
        ``EngineResult.telemetry`` — see ``docs/observability.md``.  The
        default ``None`` means off: the engine builds the exact
        pre-telemetry object graph and trajectories are bit-identical
        (pinned by ``tests/test_obs.py``).
    """
    population: int = 10
    generations: int = 500
    participation: float = 1.0          # C in the paper
    lr0: float = 0.1
    lr_decay: float = 0.995
    momentum: float = 0.5
    local_epochs: int = 1
    crossover: float = 0.9
    mutation: float = 0.1
    seed: int = 0
    aggregate_backend: str = "xla"      # Algorithm 3 route: 'xla' | 'pallas'
    backend: str = "loop"               # execution: 'loop' | 'vmap' | 'mesh'
    vmap_eval_tile: int = 32            # clients vmapped per eval scan step
    fused: bool = True                  # one dispatch per generation phase
    uplink_codec: str = "none"          # client->server payload codec
    downlink_codec: str = "none"        # server->client payload codec
    client_sim: ClientSimConfig = dataclasses.field(
        default_factory=ClientSimConfig)   # availability / dropout model
    telemetry: Optional[TelemetryConfig] = None   # repro.obs (None = off)

    def __post_init__(self):
        if self.client_sim is None:
            self.client_sim = ClientSimConfig()
        elif isinstance(self.client_sim, dict):
            self.client_sim = ClientSimConfig(**self.client_sim)
        if self.telemetry is True:
            self.telemetry = TelemetryConfig()
        elif self.telemetry is False:
            self.telemetry = None
        elif isinstance(self.telemetry, dict):
            self.telemetry = TelemetryConfig(**self.telemetry)
        if self.aggregate_backend not in AGGREGATE_BACKENDS:
            raise ValueError(
                f"unknown aggregate_backend {self.aggregate_backend!r}; "
                f"available: {list(AGGREGATE_BACKENDS)}")
        if self.vmap_eval_tile < 1:
            raise ValueError(
                f"vmap_eval_tile must be >= 1, got {self.vmap_eval_tile}")
        if not 0.0 < self.participation <= 1.0:
            raise ValueError(
                f"participation must be in (0, 1], got {self.participation}")
        if self.population < 2:
            raise ValueError(
                f"population must be >= 2 (NSGA-II needs parents to "
                f"recombine), got {self.population}")
        if self.lr0 < 0:
            raise ValueError(f"lr0 must be >= 0, got {self.lr0}")
        if self.local_epochs < 0:
            raise ValueError(
                f"local_epochs must be >= 0, got {self.local_epochs}")
        # codec specs fail here, at config time (ValueError lists the
        # available names) — the engine re-parses them when wiring
        from repro.comm import make_codec
        make_codec(self.uplink_codec)
        make_codec(self.downlink_codec)


@dataclasses.dataclass
class CommStats:
    """Cumulative server<->client traffic and compute of one run.

    Every transfer is counted on two ledgers, both independent of the
    execution backend (accounting lives in the strategies, never in the
    dispatch layer — so all backends produce identical CommStats for the
    same seed and codec):

      * **logical bytes** (``down_bytes`` / ``up_bytes`` and the eval
        subsets) — fp32 payloads, ``BYTES_PER_PARAM`` per parameter: the
        paper's Section IV.G cost unit, independent of the codec, so
        cost comparisons against the paper survive any compression
        setting.
      * **wire bytes** (``down_wire_bytes`` / ``up_wire_bytes``) — what
        the ``repro.comm`` payload codecs actually put on the network
        (``PayloadCodec.wire_bytes``).  With ``"none"`` codecs wire ==
        logical.  Choice keys and error counts are already minimal
        encodings and cross the wire uncompressed on both ledgers.

    Fields:
      * ``down_bytes``   — total logical server->client bytes: sub-model
        payload downloads (training phase) PLUS the evaluation-phase
        master / choice-key downloads.
      * ``up_bytes``     — total logical client->server bytes: sub-model
        uploads PLUS the evaluation-phase error-count uploads.
      * ``down_wire_bytes`` / ``up_wire_bytes`` — the same transfers at
        codec wire size.
      * ``client_train_passes`` — number of (individual, client) local
        training passes (E local epochs each), the paper's compute unit.
      * ``eval_down_bytes`` / ``eval_up_bytes`` — the fitness-phase
        subset of down/up_bytes (added in PR 1): per participant, the
        master download (real-time strategy only), 2N choice keys down
        (``SupernetAPI.key_bytes`` each) and one int32 error count per
        evaluated key up.  Always <= the corresponding totals.
      * ``wasted_down_bytes`` / ``wasted_down_wire_bytes`` — the subset
        of down/down_wire_bytes pushed to clients that later dropped
        out of the round (``ClientSimConfig.dropout`` / missed
        ``round_deadline``): bytes the server spent for nothing.
        Uploads have no wasted ledger — a dropped client never uploads.
        ``client_train_passes`` *does* include passes whose upload was
        lost: the device spent that compute before failing.
    """
    down_bytes: float = 0.0
    up_bytes: float = 0.0
    client_train_passes: int = 0
    eval_down_bytes: float = 0.0        # subset of down_bytes (fitness phase)
    eval_up_bytes: float = 0.0          # subset of up_bytes (fitness phase)
    down_wire_bytes: float = 0.0        # codec wire size of down_bytes
    up_wire_bytes: float = 0.0          # codec wire size of up_bytes
    wasted_down_bytes: float = 0.0      # downloads to clients that dropped
    wasted_down_wire_bytes: float = 0.0  # the same at codec wire size

    def add_download(self, params: int, copies: int = 1,
                     wire_bytes: Optional[float] = None,
                     wasted_copies: int = 0):
        """Account ``copies`` sub-model downloads of ``params`` params;
        ``wire_bytes`` is the per-payload codec wire size (defaults to
        the fp32-logical size).  ``wasted_copies`` of them (<= copies)
        went to clients that later dropped and are additionally booked
        on the wasted ledger."""
        wire = BYTES_PER_PARAM * params if wire_bytes is None else wire_bytes
        self.down_bytes += BYTES_PER_PARAM * params * copies
        self.down_wire_bytes += wire * copies
        self.wasted_down_bytes += BYTES_PER_PARAM * params * wasted_copies
        self.wasted_down_wire_bytes += wire * wasted_copies

    def add_upload(self, params: int, copies: int = 1,
                   wire_bytes: Optional[float] = None):
        """Account ``copies`` sub-model uploads of ``params`` params;
        ``wire_bytes`` as in ``add_download``."""
        self.up_bytes += BYTES_PER_PARAM * params * copies
        self.up_wire_bytes += (BYTES_PER_PARAM * params
                               if wire_bytes is None
                               else wire_bytes) * copies

    def add_eval_download_bytes(self, nbytes: float, copies: int = 1,
                                wire_nbytes: Optional[float] = None,
                                wasted_copies: int = 0):
        """Account fitness-phase downloads of ``nbytes`` logical bytes
        each (``wire_nbytes`` at codec size; defaults to ``nbytes``);
        ``wasted_copies`` as in ``add_download``."""
        wire = nbytes if wire_nbytes is None else wire_nbytes
        self.down_bytes += nbytes * copies
        self.eval_down_bytes += nbytes * copies
        self.down_wire_bytes += wire * copies
        self.wasted_down_bytes += nbytes * wasted_copies
        self.wasted_down_wire_bytes += wire * wasted_copies

    def add_eval_upload_bytes(self, nbytes: float, copies: int = 1,
                              wire_nbytes: Optional[float] = None):
        """Account fitness-phase uploads of ``nbytes`` logical bytes
        each (``wire_nbytes`` at codec size; defaults to ``nbytes``)."""
        self.up_bytes += nbytes * copies
        self.eval_up_bytes += nbytes * copies
        self.up_wire_bytes += (nbytes if wire_nbytes is None
                               else wire_nbytes) * copies


@dataclasses.dataclass
class RoundReport:
    """One federated round (== one NSGA-II generation for the NAS
    strategies).  Search fields a strategy does not produce stay ``None``
    and are dropped from the legacy history dict.

    Search fields (strategy-produced): ``objs`` is the (2N, 2) objective
    matrix [weighted test-error rate in [0, 1], forward FLOPs/MACs of the
    subnet]; ``parent_keys`` the N selected choice keys; ``best_*`` /
    ``knee_*`` the error (rate) and key of the lowest-error and
    knee-point individuals of the selected front.

    Engine-stamped fields: ``down_gb`` / ``up_gb`` are the CUMULATIVE
    CommStats totals in gigabytes (1e9 bytes) at the end of this round;
    ``train_passes`` the cumulative (individual, client) local training
    passes.  ``wall_s`` is CUMULATIVE: seconds since ``run()`` started
    (kept cumulative for the legacy history layout — it is *not* a
    per-round time); ``round_s`` is this round's wall-clock delta, the
    per-generation number benchmarks and steady-state comparisons
    want.

    Availability fields (stamped only when ``ClientSimConfig`` is
    active, ``None`` — and absent from the history dict — otherwise):
    ``n_sampled`` clients drawn by participation sampling,
    ``n_available`` of them checked in, ``n_dropped`` failed after
    download but before upload (dropout or missed deadline),
    ``n_survivors`` completed the round; ``wasted_down_gb`` is the
    cumulative wasted-download ledger in gigabytes."""
    gen: int
    objs: Optional[np.ndarray] = None          # (2N, 2) [err, flops]
    parent_keys: Optional[List[np.ndarray]] = None
    best_err: Optional[float] = None
    best_key: Optional[np.ndarray] = None
    knee_err: Optional[float] = None
    knee_key: Optional[np.ndarray] = None
    # stamped by the engine after the strategy returns:
    down_gb: float = 0.0
    up_gb: float = 0.0
    train_passes: int = 0
    wall_s: float = 0.0      # cumulative since run() start
    round_s: float = 0.0     # this round's wall-clock delta
    # client-availability simulation (None unless ClientSimConfig active):
    n_sampled: Optional[int] = None     # drawn by participation sampling
    n_available: Optional[int] = None   # actually checked in
    n_dropped: Optional[int] = None     # failed after download, pre-upload
    n_survivors: Optional[int] = None   # completed every upload
    wasted_down_gb: Optional[float] = None   # cumulative wasted ledger


HISTORY_FIELDS = ("gen", "objs", "parent_keys", "best_err", "knee_err",
                  "best_key", "knee_key", "down_gb", "up_gb",
                  "train_passes", "wall_s", "round_s", "n_sampled",
                  "n_available", "n_dropped", "n_survivors",
                  "wasted_down_gb")


def append_report(hist: Dict[str, list], report: RoundReport) -> None:
    """Append one round to a legacy dict-of-lists history in place
    (fields the strategy does not produce are dropped)."""
    for f in HISTORY_FIELDS:
        v = getattr(report, f)
        if v is not None:
            hist.setdefault(f, []).append(v)


def history_dict(reports: List[RoundReport]) -> Dict[str, list]:
    """Legacy dict-of-lists view (keys with all-None values are dropped)."""
    out: Dict[str, list] = {}
    for r in reports:
        append_report(out, r)
    return out


@dataclasses.dataclass
class EngineResult:
    reports: List[RoundReport]
    stats: CommStats
    extras: Dict
    # collected telemetry (None unless RunConfig.telemetry was enabled):
    # the retained RoundEvent ring + final per-program trace counts
    telemetry: Optional[TelemetryResult] = None

    def history(self) -> Dict:
        out = history_dict(self.reports)
        out.update(self.extras)
        out["stats"] = self.stats
        return out
