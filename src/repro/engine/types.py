"""Typed state shared by every federated NAS runtime.

``CommStats`` (moved here from ``repro.core.rt_enas``) accounts both the
training-phase traffic (sub-model downloads/uploads, Algorithm 3/4) and the
evaluation-phase traffic the paper's Section IV.G comparison needs: the 2N
choice-key downloads before fitness evaluation and the per-client
error-count uploads afterwards.  ``RoundReport`` is the typed per-round
history record every strategy produces; ``history_dict`` flattens a list of
reports into the legacy dict-of-lists layout that ``rt_enas.run`` /
``offline_enas.run`` used to return.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

BYTES_PER_PARAM = 4        # float32 payloads
ERROR_COUNT_BYTES = 4      # one int32 error count per evaluated sub-model


AGGREGATE_BACKENDS = ("xla", "pallas")
# execution backend names live in backends.BACKEND_NAMES (single source)


@dataclasses.dataclass
class RunConfig:
    """Every knob of a federated NAS run, validated at construction.

    Search / schedule:
      * ``population`` — N, individuals per generation (Algorithm 4).
      * ``generations`` — rounds to run (one NSGA-II generation == one
        federated communication round).
      * ``participation`` — C in the paper: fraction of clients sampled
        each round (m = round(C * K) participants).
      * ``lr0`` / ``lr_decay`` — client SGD learning rate, decayed as
        ``lr0 * lr_decay**(gen - 1)`` per round.
      * ``momentum`` / ``local_epochs`` — client-side SGD momentum and
        number of local passes E over the client shard per round.
      * ``crossover`` / ``mutation`` — per-offspring probabilities of the
        two variation operators (Algorithm 2).
      * ``seed`` — seeds both participant/group sampling and model init.

    Execution:
      * ``aggregate_backend`` — how Algorithm 3 (fill-aggregation) is
        computed: ``"xla"`` (jnp reference) or ``"pallas"`` (the
        ``repro.kernels.fill_aggregate`` TPU kernel; interpret-mode —
        i.e. XLA-orchestrated, Python-executed — off-TPU).  Honored by
        every execution backend; unknown values raise here, at config
        time.
      * ``backend`` — client-execution backend: ``"loop"`` (reference,
        one dispatch per (individual, client) pair), ``"vmap"``
        (ClientBatch-stacked, O(population) dispatches/gen) or ``"mesh"``
        (population axis sharded over a jax device mesh,
        O(population / devices) dispatches/gen).  Validated when the
        engine builds the backend.
      * ``vmap_eval_tile`` — clients evaluated per inner vmap tile in
        the vmap backend's forward-only eval path (>= 1).
    """
    population: int = 10
    generations: int = 500
    participation: float = 1.0          # C in the paper
    lr0: float = 0.1
    lr_decay: float = 0.995
    momentum: float = 0.5
    local_epochs: int = 1
    crossover: float = 0.9
    mutation: float = 0.1
    seed: int = 0
    aggregate_backend: str = "xla"      # Algorithm 3 route: 'xla' | 'pallas'
    backend: str = "loop"               # execution: 'loop' | 'vmap' | 'mesh'
    vmap_eval_tile: int = 32            # clients vmapped per eval scan step

    def __post_init__(self):
        if self.aggregate_backend not in AGGREGATE_BACKENDS:
            raise ValueError(
                f"unknown aggregate_backend {self.aggregate_backend!r}; "
                f"available: {list(AGGREGATE_BACKENDS)}")
        if self.vmap_eval_tile < 1:
            raise ValueError(
                f"vmap_eval_tile must be >= 1, got {self.vmap_eval_tile}")


@dataclasses.dataclass
class CommStats:
    """Cumulative server<->client traffic and compute of one run.

    All byte fields are *logical wire bytes* (float32 payloads, i.e.
    ``BYTES_PER_PARAM`` per parameter) — what the paper's Section IV.G
    cost comparison counts, independent of the execution backend.  Every
    backend therefore produces identical CommStats for the same seed.

    Fields:
      * ``down_bytes``   — total server->client bytes: sub-model payload
        downloads (training phase) PLUS the evaluation-phase master /
        choice-key downloads.
      * ``up_bytes``     — total client->server bytes: sub-model uploads
        PLUS the evaluation-phase error-count uploads.
      * ``client_train_passes`` — number of (individual, client) local
        training passes (E local epochs each), the paper's compute unit.
      * ``eval_down_bytes`` / ``eval_up_bytes`` — the fitness-phase
        subset of down/up_bytes (added in PR 1): per participant, the
        master download (real-time strategy only), 2N choice keys down
        (``SupernetAPI.key_bytes`` each) and one int32 error count per
        evaluated key up.  Always <= the corresponding totals.
    """
    down_bytes: float = 0.0
    up_bytes: float = 0.0
    client_train_passes: int = 0
    eval_down_bytes: float = 0.0        # subset of down_bytes (fitness phase)
    eval_up_bytes: float = 0.0          # subset of up_bytes (fitness phase)

    def add_download(self, params: int, copies: int = 1):
        """Account ``copies`` sub-model downloads of ``params`` params."""
        self.down_bytes += BYTES_PER_PARAM * params * copies

    def add_upload(self, params: int, copies: int = 1):
        """Account ``copies`` sub-model uploads of ``params`` params."""
        self.up_bytes += BYTES_PER_PARAM * params * copies

    def add_eval_download_bytes(self, nbytes: float, copies: int = 1):
        """Account fitness-phase downloads of ``nbytes`` bytes each."""
        self.down_bytes += nbytes * copies
        self.eval_down_bytes += nbytes * copies

    def add_eval_upload_bytes(self, nbytes: float, copies: int = 1):
        """Account fitness-phase uploads of ``nbytes`` bytes each."""
        self.up_bytes += nbytes * copies
        self.eval_up_bytes += nbytes * copies


@dataclasses.dataclass
class RoundReport:
    """One federated round (== one NSGA-II generation for the NAS
    strategies).  Search fields a strategy does not produce stay ``None``
    and are dropped from the legacy history dict.

    Search fields (strategy-produced): ``objs`` is the (2N, 2) objective
    matrix [weighted test-error rate in [0, 1], forward FLOPs/MACs of the
    subnet]; ``parent_keys`` the N selected choice keys; ``best_*`` /
    ``knee_*`` the error (rate) and key of the lowest-error and
    knee-point individuals of the selected front.

    Engine-stamped fields: ``down_gb`` / ``up_gb`` are the CUMULATIVE
    CommStats totals in gigabytes (1e9 bytes) at the end of this round;
    ``train_passes`` the cumulative (individual, client) local training
    passes; ``wall_s`` seconds since ``run()`` started."""
    gen: int
    objs: Optional[np.ndarray] = None          # (2N, 2) [err, flops]
    parent_keys: Optional[List[np.ndarray]] = None
    best_err: Optional[float] = None
    best_key: Optional[np.ndarray] = None
    knee_err: Optional[float] = None
    knee_key: Optional[np.ndarray] = None
    # stamped by the engine after the strategy returns:
    down_gb: float = 0.0
    up_gb: float = 0.0
    train_passes: int = 0
    wall_s: float = 0.0


HISTORY_FIELDS = ("gen", "objs", "parent_keys", "best_err", "knee_err",
                  "best_key", "knee_key", "down_gb", "up_gb",
                  "train_passes", "wall_s")


def append_report(hist: Dict[str, list], report: RoundReport) -> None:
    """Append one round to a legacy dict-of-lists history in place
    (fields the strategy does not produce are dropped)."""
    for f in HISTORY_FIELDS:
        v = getattr(report, f)
        if v is not None:
            hist.setdefault(f, []).append(v)


def history_dict(reports: List[RoundReport]) -> Dict[str, list]:
    """Legacy dict-of-lists view (keys with all-None values are dropped)."""
    out: Dict[str, list] = {}
    for r in reports:
        append_report(out, r)
    return out


@dataclasses.dataclass
class EngineResult:
    reports: List[RoundReport]
    stats: CommStats
    extras: Dict

    def history(self) -> Dict:
        out = history_dict(self.reports)
        out.update(self.extras)
        out["stats"] = self.stats
        return out
