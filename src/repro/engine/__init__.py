"""Federated NAS engine: strategies x execution backends.

    FedEngine(api, clients, cfg, strategy=RealTimeNas(), backend="mesh")

Strategies: RealTimeNas (Algorithm 4), OfflineNas (Zhu & Jin 2019
baseline), FedAvgBaseline (Algorithm 1, fixed architecture).
Backends: "loop" (reference, one dispatch per (individual, client)
pair), "vmap" (ClientBatch-stacked) and "mesh" (population axis sharded
over a jax device mesh); with ``RunConfig.fused`` — the default — the
batched backends run each generation as O(1) jitted dispatches (one
fill-train program with a donated master, one evaluation program
fetched by a single device_get).  Payload codecs (``RunConfig.uplink_codec`` /
``downlink_codec`` -> ``repro.comm``) compress what crosses the wire
around any strategy x backend pair.  Client availability
(``RunConfig.client_sim`` -> ``ClientSimConfig``) simulates the paper's
real-time world — per-round availability, post-download dropout,
stragglers against a deadline — with survivor-masked aggregation on
every backend and a wasted-bytes CommStats ledger.  See
docs/architecture.md for the full matrix, the round lifecycle, the
codec semantics and the availability axis.  Observability
(``RunConfig.telemetry`` -> ``repro.obs.TelemetryConfig``) records
phase spans, recompile counters, resource gauges and structured round
events without perturbing any of the above — see docs/observability.md.
"""
from repro.comm import CodecBackend, PayloadCodec, make_codec
from repro.engine.availability import ClientSimulator, RoundSim
from repro.engine.backends import BACKENDS, BACKEND_NAMES, \
    ExecutionBackend, LoopBackend, VmapBackend, make_backend
from repro.engine.engine import FedEngine
from repro.engine.mesh_backend import MeshBackend
from repro.engine.strategies import FedAvgBaseline, OfflineNas, RealTimeNas, \
    Strategy
from repro.engine.types import AGGREGATE_BACKENDS, BYTES_PER_PARAM, \
    ClientSimConfig, CommStats, EngineResult, ERROR_COUNT_BYTES, \
    RoundReport, RunConfig, history_dict
from repro.obs import InstrumentedBackend, RoundEvent, Telemetry, \
    TelemetryConfig, TelemetryResult

__all__ = [
    "AGGREGATE_BACKENDS", "BACKENDS", "BACKEND_NAMES", "BYTES_PER_PARAM",
    "ClientSimConfig", "ClientSimulator", "CodecBackend", "CommStats",
    "ERROR_COUNT_BYTES", "EngineResult", "ExecutionBackend",
    "FedAvgBaseline", "FedEngine", "InstrumentedBackend", "LoopBackend",
    "MeshBackend", "OfflineNas", "PayloadCodec", "RealTimeNas",
    "RoundEvent", "RoundReport", "RoundSim", "RunConfig", "Strategy",
    "Telemetry", "TelemetryConfig", "TelemetryResult", "VmapBackend",
    "history_dict", "make_backend", "make_codec",
]
