"""Federated NAS engine: strategies x execution backends.

    FedEngine(api, clients, cfg, strategy=RealTimeNas(), backend="vmap")

Strategies: RealTimeNas (Algorithm 4), OfflineNas (Zhu & Jin 2019
baseline), FedAvgBaseline (Algorithm 1, fixed architecture).
Backends: "loop" (reference, one dispatch per (individual, client) pair)
and "vmap" (ClientBatch-stacked, O(population) dispatches per
generation — constant in the number of clients).
"""
from repro.engine.backends import BACKENDS, ExecutionBackend, LoopBackend, \
    VmapBackend, make_backend
from repro.engine.engine import FedEngine
from repro.engine.strategies import FedAvgBaseline, OfflineNas, RealTimeNas, \
    Strategy
from repro.engine.types import BYTES_PER_PARAM, CommStats, EngineResult, \
    ERROR_COUNT_BYTES, RoundReport, RunConfig, history_dict

__all__ = [
    "BACKENDS", "BYTES_PER_PARAM", "CommStats", "ERROR_COUNT_BYTES",
    "EngineResult", "ExecutionBackend", "FedAvgBaseline", "FedEngine",
    "LoopBackend", "OfflineNas", "RealTimeNas", "RoundReport", "RunConfig",
    "Strategy", "VmapBackend", "history_dict", "make_backend",
]
