"""Federated search strategies: what differs between the paper's
Algorithms 1/4 and the offline baseline, and nothing else.

The engine owns participant sampling, the lr schedule, comm accounting
totals and the round loop; the execution backend owns how local SGD and
evaluation are dispatched.  A strategy only sequences the round:

  * ``RealTimeNas``   — Algorithm 4: weight-inherited sub-models,
    fill-aggregation into one shared master, 2N-wide fitness evaluation,
    NSGA-II environmental selection.  One training pass per client per
    generation (the paper's real-time claim).
  * ``OfflineNas``    — the Zhu & Jin 2019 baseline: every offspring is
    reinitialized, every client trains every individual, plain FedAvg per
    individual, no shared master.
  * ``FedAvgBaseline``— Algorithm 1 on a fixed architecture (the paper's
    ResNet18 role in Table IV).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Protocol

import jax
import numpy as np

from repro.core.choice import make_offspring
from repro.core.double_sampling import sample_client_groups, \
    sample_population_keys
from repro.core.nsga2 import fast_non_dominated_sort, knee_point, select
from repro.engine.types import BYTES_PER_PARAM, ERROR_COUNT_BYTES, \
    RoundReport


class Strategy(Protocol):
    """What differs between the paper's algorithms, and nothing else."""

    name: str

    def setup(self, engine) -> None:
        """Initialize run state (models, parent keys) before round 1;
        called by every ``FedEngine.run`` so runs are re-entrant."""
        ...

    def round(self, engine, gen: int, participants: np.ndarray,
              lr: float) -> RoundReport:
        """Execute one federated round (= one generation): sequence the
        backend's train/eval calls, account traffic on ``engine.stats``
        and return the round's ``RoundReport``.  ``gen`` is 1-based;
        ``participants`` the sampled client ids; ``lr`` this round's
        client learning rate."""
        ...

    def extras(self, engine) -> Dict:
        """Run-level outputs merged into ``EngineResult.extras`` (e.g.
        the final master parameters)."""
        ...


def _account_train(engine, keys, groups, download_models: bool):
    """Training-phase traffic of one fill-aggregated generation: payload
    down (t == 1 only — later rounds inherit weights already on device),
    payload up, one local pass per (individual, client) pair.  Logical
    bytes are fp32; wire bytes come from the run's payload codecs."""
    stats, api = engine.stats, engine.api
    down, up = engine.downlink_codec, engine.uplink_codec
    for key, group in zip(keys, groups):
        payload = api.payload_params(key)
        for _ in group:
            if download_models:
                stats.add_download(payload,      # theta^q + key (t == 1)
                                   wire_bytes=down.wire_bytes(payload))
            stats.add_upload(payload, wire_bytes=up.wire_bytes(payload))
            stats.client_train_passes += 1


def _account_eval(engine, n_keys: int, n_participants: int,
                  master_params: Optional[int] = None):
    """Fitness-phase traffic (Section IV.G): the aggregated-model
    download when the strategy broadcasts one (real-time NAS's master,
    the FedAvg baseline's model — at downlink-codec wire size), the
    n_keys choice-key downloads, and one error-count upload per
    (key, client) pair (keys and counts are already minimal encodings —
    wire == logical)."""
    stats, api = engine.stats, engine.api
    if master_params is not None:
        stats.add_eval_download_bytes(
            BYTES_PER_PARAM * master_params, copies=n_participants,
            wire_nbytes=engine.downlink_codec.wire_bytes(master_params))
    stats.add_eval_download_bytes(api.key_bytes * n_keys,
                                  copies=n_participants)
    stats.add_eval_upload_bytes(ERROR_COUNT_BYTES * n_keys,
                                copies=n_participants)


class RealTimeNas:
    """The paper's Algorithm 4 (one NSGA-II generation == one round)."""

    name = "realtime"

    def __init__(self):
        self.master = None
        self.parents: List[np.ndarray] = []

    def setup(self, engine):
        cfg = engine.cfg
        self.master = engine.api.init(jax.random.PRNGKey(cfg.seed))
        self.parents = sample_population_keys(engine.rng, cfg.population,
                                              engine.api.num_blocks)

    def round(self, engine, gen, participants, lr):
        cfg, api, backend = engine.cfg, engine.api, engine.backend

        # --- t == 1 only: train the parent sub-models (Algorithm 4 l.15-26)
        if gen == 1:
            groups = sample_client_groups(engine.rng, participants,
                                          cfg.population)
            _account_train(engine, self.parents, groups, download_models=True)
            self.master = backend.train_fill(self.master, self.parents,
                                             groups, lr)

        # --- offspring: inherit weights, never reinitialize (l.27-41)
        offspring = make_offspring(engine.rng, self.parents, cfg.population,
                                   cfg.crossover, cfg.mutation)
        groups = sample_client_groups(engine.rng, participants,
                                      cfg.population)
        _account_train(engine, offspring, groups,
                       download_models=(gen == 1))
        self.master = backend.train_fill(self.master, offspring, groups, lr)

        # --- fitness: master + all 2N keys to every participant (l.43-49)
        combined = list(self.parents) + list(offspring)
        _account_eval(engine, len(combined), len(participants),
                      master_params=api.master_params())
        errs = backend.eval_shared(self.master, combined, participants)
        fl = np.array([api.flops(k) for k in combined], dtype=float)
        objs = np.stack([errs, fl], axis=1)

        # --- NSGA-II environmental selection (l.50-53)
        sel = select(objs, cfg.population)
        self.parents = [combined[i] for i in sel]
        front0 = fast_non_dominated_sort(objs[sel])[0]
        knee_local = knee_point(objs[sel], front0)
        best_local = sel[int(np.argmin(objs[sel][:, 0]))]

        return RoundReport(
            gen=gen, objs=objs,
            parent_keys=[k.copy() for k in self.parents],
            best_err=float(objs[best_local, 0]),
            best_key=combined[best_local].copy(),
            knee_err=float(objs[sel][knee_local, 0]),
            knee_key=combined[sel[knee_local]].copy())

    def extras(self, engine):
        return {"final_master": self.master}


class OfflineNas:
    """Offline evolutionary federated NAS (Zhu & Jin 2019): reinitialized
    individuals, every client trains every individual, per-individual
    FedAvg — the paper's Section IV.G cost comparison baseline."""

    name = "offline"

    def __init__(self):
        self.parents: List[np.ndarray] = []
        self.parent_objs: Optional[np.ndarray] = None
        self._reinit_seed = 1000

    def setup(self, engine):
        self.parents = sample_population_keys(engine.rng,
                                              engine.cfg.population,
                                              engine.api.num_blocks)
        self.parent_objs = None
        self._reinit_seed = 1000

    def _train_and_eval(self, engine, keys, participants, lr):
        api, stats, backend = engine.api, engine.stats, engine.backend
        m = len(participants)
        inits = []
        for _ in keys:
            self._reinit_seed += 1
            # REINITIALIZED from scratch — the paper's central criticism
            inits.append(api.init(jax.random.PRNGKey(self._reinit_seed)))
        down, up = engine.downlink_codec, engine.uplink_codec
        payloads = [api.payload_params(k) for k in keys]
        for payload in payloads:                 # every client trains
            stats.add_download(payload, copies=m,
                               wire_bytes=down.wire_bytes(payload))
            stats.add_upload(payload, copies=m,
                             wire_bytes=up.wire_bytes(payload))
            stats.client_train_passes += m
        models = backend.train_fedavg_population(inits, keys,
                                                 participants, lr)
        for payload in payloads:                 # aggregated model for eval
            stats.add_eval_download_bytes(
                BYTES_PER_PARAM * payload, copies=m,
                wire_nbytes=down.wire_bytes(payload))
        stats.add_eval_upload_bytes(ERROR_COUNT_BYTES * len(keys), copies=m)
        errs = backend.eval_paired(models, keys, participants)
        fl = [api.flops(k) for k in keys]
        return np.stack([errs, np.asarray(fl, dtype=float)], axis=1)

    def round(self, engine, gen, participants, lr):
        cfg = engine.cfg
        if self.parent_objs is None:
            self.parent_objs = self._train_and_eval(engine, self.parents,
                                                    participants, lr)
        offspring = make_offspring(engine.rng, self.parents, cfg.population,
                                   cfg.crossover, cfg.mutation)
        off_objs = self._train_and_eval(engine, offspring, participants, lr)

        combined = list(self.parents) + list(offspring)
        objs = np.concatenate([self.parent_objs, off_objs], axis=0)
        sel = select(objs, cfg.population)
        self.parents = [combined[i] for i in sel]
        self.parent_objs = objs[sel]

        return RoundReport(
            gen=gen, objs=objs,
            parent_keys=[k.copy() for k in self.parents],
            best_err=float(objs[sel][:, 0].min()))

    def extras(self, engine):
        return {}


class FedAvgBaseline:
    """Algorithm 1 on one fixed choice key (the ResNet18 role)."""

    name = "fedavg"

    def __init__(self, key: np.ndarray):
        self.key = np.asarray(key, np.int32)
        self.params = None

    def setup(self, engine):
        self.params = engine.api.init(jax.random.PRNGKey(engine.cfg.seed))

    def round(self, engine, gen, participants, lr):
        stats, api, backend = engine.stats, engine.api, engine.backend
        m = len(participants)
        payload = api.payload_params(self.key)
        stats.add_download(
            payload, copies=m,
            wire_bytes=engine.downlink_codec.wire_bytes(payload))
        stats.add_upload(
            payload, copies=m,
            wire_bytes=engine.uplink_codec.wire_bytes(payload))
        stats.client_train_passes += m
        self.params = backend.train_fedavg(self.params, self.key,
                                           participants, lr)
        _account_eval(engine, 1, m, master_params=payload)
        err = backend.eval_shared(self.params, [self.key], participants)[0]
        return RoundReport(gen=gen, best_err=float(err))

    def extras(self, engine):
        return {"params": self.params,
                "flops": engine.api.flops(self.key)}
