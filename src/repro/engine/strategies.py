"""Federated search strategies: what differs between the paper's
Algorithms 1/4 and the offline baseline, and nothing else.

The engine owns participant sampling, the lr schedule, comm accounting
totals and the round loop; the execution backend owns how local SGD and
evaluation are dispatched.  A strategy only sequences the round:

  * ``RealTimeNas``   — Algorithm 4: weight-inherited sub-models,
    fill-aggregation into one shared master, 2N-wide fitness evaluation,
    NSGA-II environmental selection.  One training pass per client per
    generation (the paper's real-time claim).
  * ``OfflineNas``    — the Zhu & Jin 2019 baseline: every offspring is
    reinitialized, every client trains every individual, plain FedAvg per
    individual, no shared master.
  * ``FedAvgBaseline``— Algorithm 1 on a fixed architecture (the paper's
    ResNet18 role in Table IV).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Protocol, Sequence

import jax
import numpy as np

from repro.core.choice import make_offspring
from repro.core.double_sampling import sample_client_groups, \
    sample_population_keys
from repro.core.nsga2 import fast_non_dominated_sort, knee_point, select
from repro.engine.availability import RoundSim
from repro.engine.types import BYTES_PER_PARAM, ERROR_COUNT_BYTES, \
    RoundReport
from repro.obs import NULL_TELEMETRY


class Strategy(Protocol):
    """What differs between the paper's algorithms, and nothing else."""

    name: str

    def setup(self, engine) -> None:
        """Initialize run state (models, parent keys) before round 1;
        called by every ``FedEngine.run`` so runs are re-entrant."""
        ...

    def round(self, engine, gen: int, participants: np.ndarray,
              lr: float) -> RoundReport:
        """Execute one federated round (= one generation): sequence the
        backend's train/eval calls, account traffic on ``engine.stats``
        and return the round's ``RoundReport``.  ``gen`` is 1-based;
        ``participants`` the client ids that checked in this round
        (availability-filtered by the engine); ``lr`` this round's
        client learning rate.  ``engine.round_ctx`` carries the round's
        availability outcome (``RoundSim``) — ``survivors`` must be
        passed to every backend call and dropped clients' downloads
        booked as wasted."""
        ...

    def extras(self, engine) -> Dict:
        """Run-level outputs merged into ``EngineResult.extras`` (e.g.
        the final master parameters)."""
        ...


def _round_ctx(engine, participants) -> RoundSim:
    """The round's availability outcome; a fresh inactive one when the
    engine never drew a round (strategies driven outside FedEngine)."""
    ctx = getattr(engine, "round_ctx", None)
    if ctx is None:
        return RoundSim.inactive(np.asarray(participants))
    return ctx


def _telemetry(engine):
    """The engine's telemetry, or the shared no-op for strategies driven
    outside FedEngine (same duck-typing as ``_round_ctx``)."""
    return getattr(engine, "telemetry", NULL_TELEMETRY)


def _account_train(engine, keys, groups, download_models: bool,
                   ctx: RoundSim):
    """Training-phase traffic of one fill-aggregated generation: payload
    down (t == 1 only — later rounds inherit weights already on device),
    payload up, one local pass per (individual, client) pair.  Logical
    bytes are fp32; wire bytes come from the run's payload codecs.
    Dropped clients (``ctx.dropped``) fail after download, before
    upload: their downloads land on the wasted ledger, their passes
    count (the device spent that compute) and they upload nothing."""
    stats, api = engine.stats, engine.api
    down, up = engine.downlink_codec, engine.uplink_codec
    dropped = {int(c) for c in ctx.dropped}
    for key, group in zip(keys, groups):
        payload = api.payload_params(key)
        for cid in group:
            dead = int(cid) in dropped
            if download_models:
                stats.add_download(payload,      # theta^q + key (t == 1)
                                   wire_bytes=down.wire_bytes(payload),
                                   wasted_copies=int(dead))
            stats.client_train_passes += 1
            if not dead:
                stats.add_upload(payload, wire_bytes=up.wire_bytes(payload))


def _account_eval(engine, n_keys: int, ctx: RoundSim,
                  model_params: Sequence[int] = ()):
    """Fitness-phase traffic (Section IV.G): every broadcast
    aggregated-model download (real-time NAS's master, the FedAvg
    baseline's model, the offline baseline's per-individual models — at
    downlink-codec wire size), the n_keys choice-key downloads, and one
    error-count upload per (key, client) pair (keys and counts are
    already minimal encodings — wire == logical).  Every strategy
    routes its fitness accounting through here, so the Section IV.G
    offline-vs-realtime comparison counts the same transfer kinds on
    both sides.  Downloads go to every participant (the round's
    communication plan is fixed before anyone fails) — the dropped
    clients' share is booked as wasted — while only survivors upload
    counts."""
    stats, api = engine.stats, engine.api
    n_participants = len(ctx.participants)
    n_wasted = ctx.n_dropped
    for p in model_params:
        stats.add_eval_download_bytes(
            BYTES_PER_PARAM * p, copies=n_participants,
            wire_nbytes=engine.downlink_codec.wire_bytes(p),
            wasted_copies=n_wasted)
    stats.add_eval_download_bytes(api.key_bytes * n_keys,
                                  copies=n_participants,
                                  wasted_copies=n_wasted)
    stats.add_eval_upload_bytes(ERROR_COUNT_BYTES * n_keys,
                                copies=ctx.n_survivors)


class RealTimeNas:
    """The paper's Algorithm 4 (one NSGA-II generation == one round)."""

    name = "realtime"

    def __init__(self):
        self.master = None
        self.parents: List[np.ndarray] = []

    def setup(self, engine):
        cfg = engine.cfg
        self.master = engine.api.init(jax.random.PRNGKey(cfg.seed))
        self.parents = sample_population_keys(engine.rng, cfg.population,
                                              engine.api.num_blocks)

    def round(self, engine, gen, participants, lr):
        cfg, api, backend = engine.cfg, engine.api, engine.backend
        ctx = _round_ctx(engine, participants)
        tel = _telemetry(engine)
        survivors = ctx.survivors

        # short groups are only legitimate when clients can actually be
        # absent — a synchronous run short of clients is a misconfig
        strict = not ctx.active

        # --- t == 1 only: train the parent sub-models (Algorithm 4 l.15-26)
        if gen == 1:
            with tel.span("sample"):
                groups = sample_client_groups(engine.rng, participants,
                                              cfg.population, strict=strict)
            _account_train(engine, self.parents, groups,
                           download_models=True, ctx=ctx)
            if ctx.n_survivors:
                self.master = backend.train_fill(self.master, self.parents,
                                                 groups, lr,
                                                 survivors=survivors)

        # --- offspring: inherit weights, never reinitialize (l.27-41)
        with tel.span("sample"):
            offspring = make_offspring(engine.rng, self.parents,
                                       cfg.population, cfg.crossover,
                                       cfg.mutation)
            groups = sample_client_groups(engine.rng, participants,
                                          cfg.population, strict=strict)
        _account_train(engine, offspring, groups,
                       download_models=(gen == 1), ctx=ctx)
        if ctx.n_survivors:
            self.master = backend.train_fill(self.master, offspring, groups,
                                             lr, survivors=survivors)

        # --- fitness: master + all 2N keys to every participant (l.43-49)
        combined = list(self.parents) + list(offspring)
        _account_eval(engine, len(combined), ctx,
                      model_params=[api.master_params()])
        if ctx.n_survivors:
            errs = backend.eval_shared(self.master, combined, participants,
                                       survivors=survivors)
        else:
            # nobody reported: no fitness signal this round — selection
            # falls back to the FLOPs objective (pessimistic error 1.0)
            errs = np.ones(len(combined))
        fl = np.array([api.flops(k) for k in combined], dtype=float)
        objs = np.stack([errs, fl], axis=1)

        # --- NSGA-II environmental selection (l.50-53)
        with tel.span("aggregate"):
            sel = select(objs, cfg.population)
            self.parents = [combined[i] for i in sel]
            front0 = fast_non_dominated_sort(objs[sel])[0]
            knee_local = knee_point(objs[sel], front0)
            best_local = sel[int(np.argmin(objs[sel][:, 0]))]

        return RoundReport(
            gen=gen, objs=objs,
            parent_keys=[k.copy() for k in self.parents],
            best_err=float(objs[best_local, 0]),
            best_key=combined[best_local].copy(),
            knee_err=float(objs[sel][knee_local, 0]),
            knee_key=combined[sel[knee_local]].copy())

    def extras(self, engine):
        return {"final_master": self.master}


class OfflineNas:
    """Offline evolutionary federated NAS (Zhu & Jin 2019): reinitialized
    individuals, every client trains every individual, per-individual
    FedAvg — the paper's Section IV.G cost comparison baseline."""

    name = "offline"

    def __init__(self):
        self.parents: List[np.ndarray] = []
        self.parent_objs: Optional[np.ndarray] = None
        self._reinit_seed = 1000

    def setup(self, engine):
        self.parents = sample_population_keys(engine.rng,
                                              engine.cfg.population,
                                              engine.api.num_blocks)
        self.parent_objs = None
        self._reinit_seed = 1000

    def _train_and_eval(self, engine, keys, participants, lr):
        api, stats, backend = engine.api, engine.stats, engine.backend
        ctx = _round_ctx(engine, participants)
        m = len(participants)
        n_dropped = ctx.n_dropped
        inits = []
        for _ in keys:
            self._reinit_seed += 1
            # REINITIALIZED from scratch — the paper's central criticism
            inits.append(api.init(jax.random.PRNGKey(self._reinit_seed)))
        down, up = engine.downlink_codec, engine.uplink_codec
        payloads = [api.payload_params(k) for k in keys]
        for payload in payloads:                 # every client trains
            stats.add_download(payload, copies=m,
                               wire_bytes=down.wire_bytes(payload),
                               wasted_copies=n_dropped)
            stats.add_upload(payload, copies=ctx.n_survivors,
                             wire_bytes=up.wire_bytes(payload))
            stats.client_train_passes += m
        if ctx.n_survivors:
            models = backend.train_fedavg_population(
                inits, keys, participants, lr, survivors=ctx.survivors)
        else:
            models = inits               # no uploads: FedAvg is a no-op
        # fitness phase: per-individual aggregated models + choice keys
        # down, error counts up — through the same accounting helper as
        # the real-time strategy, so Section IV.G counts both sides alike
        _account_eval(engine, len(keys), ctx, model_params=payloads)
        if ctx.n_survivors:
            errs = backend.eval_paired(models, keys, participants,
                                       survivors=ctx.survivors)
        else:
            errs = np.ones(len(keys))
        fl = [api.flops(k) for k in keys]
        return np.stack([errs, np.asarray(fl, dtype=float)], axis=1)

    def round(self, engine, gen, participants, lr):
        cfg = engine.cfg
        tel = _telemetry(engine)
        if self.parent_objs is None:
            self.parent_objs = self._train_and_eval(engine, self.parents,
                                                    participants, lr)
        with tel.span("sample"):
            offspring = make_offspring(engine.rng, self.parents,
                                       cfg.population, cfg.crossover,
                                       cfg.mutation)
        off_objs = self._train_and_eval(engine, offspring, participants, lr)

        combined = list(self.parents) + list(offspring)
        objs = np.concatenate([self.parent_objs, off_objs], axis=0)
        with tel.span("aggregate"):
            sel = select(objs, cfg.population)
            self.parents = [combined[i] for i in sel]
            self.parent_objs = objs[sel]

        return RoundReport(
            gen=gen, objs=objs,
            parent_keys=[k.copy() for k in self.parents],
            best_err=float(objs[sel][:, 0].min()))

    def extras(self, engine):
        return {}


class FedAvgBaseline:
    """Algorithm 1 on one fixed choice key (the ResNet18 role)."""

    name = "fedavg"

    def __init__(self, key: np.ndarray):
        self.key = np.asarray(key, np.int32)
        self.params = None

    def setup(self, engine):
        self.params = engine.api.init(jax.random.PRNGKey(engine.cfg.seed))

    def round(self, engine, gen, participants, lr):
        stats, api, backend = engine.stats, engine.api, engine.backend
        ctx = _round_ctx(engine, participants)
        m = len(participants)
        payload = api.payload_params(self.key)
        stats.add_download(
            payload, copies=m,
            wire_bytes=engine.downlink_codec.wire_bytes(payload),
            wasted_copies=ctx.n_dropped)
        stats.add_upload(
            payload, copies=ctx.n_survivors,
            wire_bytes=engine.uplink_codec.wire_bytes(payload))
        stats.client_train_passes += m
        if ctx.n_survivors:
            self.params = backend.train_fedavg(self.params, self.key,
                                               participants, lr,
                                               survivors=ctx.survivors)
        _account_eval(engine, 1, ctx, model_params=[payload])
        if ctx.n_survivors:
            err = backend.eval_shared(self.params, [self.key], participants,
                                      survivors=ctx.survivors)[0]
        else:
            err = 1.0                    # nobody reported this round
        return RoundReport(gen=gen, best_err=float(err))

    def extras(self, engine):
        return {"params": self.params,
                "flops": engine.api.flops(self.key)}
