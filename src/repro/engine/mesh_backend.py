"""MeshBackend: device-mesh-sharded client execution for FedEngine.

The double-sampling design (paper Algorithm 4) makes every generation an
embarrassingly parallel population x client-group workload: group g
trains individual g's sub-model, and the 2N fitness evaluations are
independent.  ``VmapBackend`` already turns that structure into
O(population) jitted dispatches; this backend additionally shards the
*population axis* of the same ``ClientBatch``-stacked tensors over a
``jax.sharding.Mesh`` (``launch.mesh.make_host_mesh`` by default, any
mesh — e.g. ``make_production_mesh()`` — via the ``mesh=`` argument), so
a generation costs O(population / devices) dispatches and each device
only touches its slice of the population:

  * ``train_fill``   — (group, client)-stacked shards are gathered from
    the resident train store, padded to the mesh size, placed with
    ``NamedSharding`` (``launch.sharding.batch_spec``) and consumed by
    one ``shard_map`` program per shape bucket that fuses local SGD with
    the fill-aggregation partial sum (Algorithm 3); a ``psum`` over the
    population axes yields the replicated new master.
  * ``train_fedavg_population`` — individuals (stacked parameters +
    keys) are sharded over the mesh; every device FedAvg-trains its
    slice of the population on the (replicated) participant shards.
  * ``eval_shared`` / ``eval_paired`` — the 2N choice keys (and paired
    parameter stacks) are sharded; each device counts test errors for
    its keys over the replicated stacked test set, one dispatch per
    shape bucket for the WHOLE key batch.

With ``RunConfig.fused`` (the default) the per-bucket dispatches above
collapse to O(1) per generation: the shard_map programs are traceable,
so one jitted wrapper per phase loops the shape buckets *inside* the
dispatch — one ``train_fill`` program (master donated off-CPU when
``backends.master_donation_safe``) and one evaluation program whose
(2N,) wrong-count vector is fetched with a single ``jax.device_get``.
The program bodies themselves are shared with ``VmapBackend``
(``repro.engine.backends``: ``fill_bucket_partial``,
``eval_bucket_counts``, ...), which is what keeps reduction order — and
therefore parity — aligned across backends.  The
``aggregate_backend="pallas"`` route stays partially fused (sharded SGD
uploads per bucket, Algorithm 3 in the kernel outside the program).

Inside a shard every (individual, client) pair runs under ``lax.scan``
with the choice key a traced *scalar*, so ``lax.switch`` in the model
forward stays a real branch (vmapping the key axis would lower to
compute-all-branches-and-select — the 3-4x blowup documented on
``VmapBackend``).

Determinism / parity: padding rows carry weight 0 and weights are
normalized globally, so results match ``VmapBackend`` within fp32
reduction-order noise (<= 1e-5 on the smoke supernet; asserted by
``tests/test_engine.py``) and CommStats — which the strategies account,
independent of execution — match exactly.  Client dropout
(``ClientSimConfig``) rides the same weight-0 mechanism for training
and an int32 ``alive`` mask for the eval counts, so the sharded shapes
— and the O(1) fused dispatch count — are unchanged at any dropout
rate (see ``repro.engine.backends``).

Run multi-device on a plain CPU host with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set before jax
is imported; this is how CI exercises an 8-way mesh).

``RunConfig.aggregate_backend`` is honored like every other backend:
``"xla"`` uses the fused partial-sum path above; ``"pallas"`` returns
the sharded uploads and routes Algorithm 3 through the
``repro.kernels.fill_aggregate`` kernel via ``fill_aggregate_stacked``.

Payload codecs (``RunConfig.uplink_codec`` / ``downlink_codec``) are
likewise honored without touching the shard_map programs: ``FedEngine``
wraps this backend in ``repro.comm.backend.CodecBackend``, which
compresses the master each program consumes and the aggregated update
each ``train_fill`` produces — the fused SGD+Algorithm-3 psum path and
its reduction-order guarantees are codec-independent.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core.aggregate import fill_aggregate_stacked
from repro.core.federated import client_update_fn, eval_count_fn
from repro.core.supernet import SupernetAPI
from repro.data.pipeline import ClientDataset
from repro.engine.backends import StackedClientBase, accumulate_parts, \
    cast_like, eval_bucket_counts, eval_paired_bucket_counts, \
    fedavg_population_bucket, fill_bucket_partial, master_donation_safe, \
    train_bucket_uploads
from repro.engine.types import RunConfig
from repro.launch.mesh import data_axes, make_host_mesh, mesh_axis_size
from repro.launch.sharding import batch_spec
from repro.obs import traced


class MeshBackend(StackedClientBase):
    """Population-axis-sharded execution over a jax device mesh.

    Args (beyond the ``ExecutionBackend`` constructor contract):
      * ``mesh`` — optional ``jax.sharding.Mesh``; defaults to
        ``launch.mesh.make_host_mesh()`` (all local devices on one
        ``data`` axis).  The population axis is sharded over
        ``launch.mesh.data_axes(mesh)``; the ``model`` axis is left for
        future tensor-parallel masters and must currently be size 1 in
        the axes this backend shards over.
    """

    name = "mesh"

    def __init__(self, api: SupernetAPI, clients: Sequence[ClientDataset],
                 cfg: RunConfig, mesh: Optional[Mesh] = None):
        super().__init__(api, clients, cfg)
        self.mesh = mesh if mesh is not None else make_host_mesh()
        self.axes = data_axes(self.mesh)
        self.num_devices = mesh_axis_size(self.mesh, self.axes)
        upd = client_update_fn(api, cfg.local_epochs, cfg.momentum)
        ev = eval_count_fn(api)
        mask_fn = api.trained_mask
        axes = self.axes
        pop = PartitionSpec(axes)       # leading axis sharded, rest replicated
        rep = PartitionSpec()
        self.donate_master = (cfg.fused and master_donation_safe(cfg)
                              and jax.default_backend() != "cpu")

        # The program bodies are the shared fused-bucket bodies from
        # repro.engine.backends — shard_map slices the population axis,
        # each device runs the identical body on its slice (so the vmap
        # backend's fp32 reduction order is preserved expression for
        # expression under sharding), and train adds a psum.

        # -- train_fill: fused local SGD + Algorithm 3 partial sum ----------
        def fill_body(master, keys, xb, yb, w, lr):
            # local shapes: keys (Gl, nb); xb/yb (Gl, S, nbat, B, ...);
            # w (Gl, S) globally normalized (0 = padding)
            return jax.lax.psum(
                fill_bucket_partial(upd, mask_fn, master,
                                    keys, xb, yb, w, lr), axes)

        fill_sm = shard_map(
            fill_body, mesh=self.mesh,
            in_specs=(rep, pop, pop, pop, pop, rep),
            out_specs=rep, check_rep=False)
        # every jitted program is wrapped by repro.obs.traced (recompile
        # counter + named_scope label, exactly as in VmapBackend); the
        # fused wrappers below call the RAW shard_map callables, so each
        # trace bumps exactly one counter — no double counting
        tc = self.trace_counts
        self._fill_partial = jax.jit(traced("fill_partial", tc, fill_sm))

        # -- train_fill, kernel route: sharded SGD, uploads come back ------
        def uploads_body(master, keys, xb, yb, lr):
            return train_bucket_uploads(upd, master, keys, xb, yb, lr)

        self._train_uploads = jax.jit(traced("train_uploads", tc, shard_map(
            uploads_body, mesh=self.mesh,
            in_specs=(rep, pop, pop, pop, rep),
            out_specs=pop, check_rep=False)))

        # -- per-individual FedAvg over replicated participants -------------
        def fedavg_body(ps, keys, xb, yb, wn, lr):
            # ps leaves (Pl, ...), keys (Pl, nb) sharded;
            # xb/yb (S, nbat, B, ...) and wn (S,) replicated
            return fedavg_population_bucket(upd, ps, keys, xb, yb, wn, lr)

        fedavg_sm = shard_map(
            fedavg_body, mesh=self.mesh,
            in_specs=(pop, pop, rep, rep, rep, rep),
            out_specs=pop, check_rep=False)
        self._fedavg_partial = jax.jit(traced("fedavg_partial", tc,
                                              fedavg_sm))

        # -- sharded-key evaluation over the replicated test stack ----------
        # (``alive`` is the replicated int32 survivor mask — dropped
        # clients' counts are zeroed inside the program, so the sharded
        # shapes stay static under any dropout rate)
        def eval_shared_body(params, keys, xb, yb, alive):
            return eval_bucket_counts(ev, params, keys, xb, yb, alive,
                                      tile=cfg.vmap_eval_tile)

        eval_shared_sm = shard_map(
            eval_shared_body, mesh=self.mesh,
            in_specs=(rep, pop, rep, rep, rep),
            out_specs=pop, check_rep=False)
        self._eval_shared_counts = jax.jit(traced("eval_shared_counts", tc,
                                                  eval_shared_sm))

        def eval_paired_body(ps, keys, xb, yb, alive):
            return eval_paired_bucket_counts(ev, ps, keys, xb, yb, alive,
                                             tile=cfg.vmap_eval_tile)

        eval_paired_sm = shard_map(
            eval_paired_body, mesh=self.mesh,
            in_specs=(pop, pop, rep, rep, rep),
            out_specs=pop, check_rep=False)
        self._eval_paired_counts = jax.jit(traced("eval_paired_counts", tc,
                                                  eval_paired_sm))

        # -- fused composition (cfg.fused): the shard_map programs above
        # are traceable, so one jitted wrapper per phase loops the shape
        # buckets INSIDE the dispatch — O(1) dispatches per generation,
        # and the master is donated off-CPU like the vmap backend.  The
        # combiners are the shared ones (accumulate_parts / cast_like),
        # only the per-bucket callable differs (shard_map-wrapped).
        def fused_fill(master, buckets, lr):
            return cast_like(accumulate_parts(
                fill_sm(master, keys, xb, yb, w, lr)
                for keys, xb, yb, w in buckets), master)

        def fused_eval_shared(params, keys, shards):
            return accumulate_parts(
                eval_shared_sm(params, keys, xb, yb, alive)
                for xb, yb, alive in shards)

        def fused_eval_paired(ps, keys, shards):
            return accumulate_parts(
                eval_paired_sm(ps, keys, xb, yb, alive)
                for xb, yb, alive in shards)

        def fused_fedavg(ps, keys, buckets, lr):
            return cast_like(accumulate_parts(
                fedavg_sm(ps, keys, xb, yb, wn, lr)
                for xb, yb, wn in buckets), ps)

        self._fused_fill = jax.jit(
            traced("fused_fill", tc, fused_fill),
            donate_argnums=(0,) if self.donate_master else ())
        self._fused_eval_shared = jax.jit(traced("fused_eval_shared", tc,
                                                 fused_eval_shared))
        self._fused_eval_paired = jax.jit(traced("fused_eval_paired", tc,
                                                 fused_eval_paired))
        self._fused_fedavg = jax.jit(traced("fused_fedavg", tc,
                                            fused_fedavg))

    # -- placement helpers --------------------------------------------------

    def _pad(self, n: int) -> int:
        """Rows to append so the leading axis divides the mesh."""
        return (-n) % self.num_devices

    def _put_pop(self, arr):
        """Place one stacked array with its leading (population) axis
        sharded over the mesh's data axes (``launch.sharding.batch_spec``)."""
        arr = jnp.asarray(arr)
        spec = batch_spec(self.mesh, arr.shape[0], arr.ndim)
        return jax.device_put(arr, NamedSharding(self.mesh, spec))

    def _put_pop_tree(self, tree):
        return jax.tree.map(self._put_pop, tree)

    def _place_test(self, arr):
        """Replicate the cached test stacks over the mesh once, so the
        eval programs (in_specs=rep) never re-transfer them."""
        return jax.device_put(jnp.asarray(arr),
                              NamedSharding(self.mesh, PartitionSpec()))

    # -- train_fill ----------------------------------------------------------

    def _group_bucket_arrays(self, keys, groups, total, pad_groups=None,
                             place=None, survivors=None, store=None):
        """The base builder with the group axis padded to a mesh multiple
        and every array placed population-sharded (weight-0 padding,
        which also carries the dropped-client survivor masking)."""
        g_pad = self._pad(len(groups)) if pad_groups is None else pad_groups
        return super()._group_bucket_arrays(
            keys, groups, total, pad_groups=g_pad,
            place=self._put_pop if place is None else place,
            survivors=survivors, store=store)

    def train_fill(self, master, keys, groups, lr, survivors=None):
        groups = [np.asarray(g) for g in groups]
        total = self._survivor_total([c for g in groups for c in g],
                                     survivors)
        if total == 0.0:
            return master
        buckets = self._group_bucket_arrays(keys, groups, total,
                                            survivors=survivors)
        if not buckets:
            return master
        if self.cfg.aggregate_backend == "pallas":
            return self._train_fill_pallas(master, buckets, lr)
        lr = jnp.float32(lr)
        if self.cfg.fused:
            # one dispatch for the whole generation's fill-train (the
            # bucket loop runs inside the program; donated master)
            out = self._fused_fill(master, tuple(buckets), lr)
            self.dispatches += 1
            return out
        acc = None
        for keys_a, xb, yb, w in buckets:
            part = self._fill_partial(master, keys_a, xb, yb, w, lr)
            self.dispatches += 1
            acc = part if acc is None else jax.tree.map(jnp.add, acc, part)
        return jax.tree.map(lambda a, p: a.astype(p.dtype), acc, master)

    def _train_fill_pallas(self, master, buckets, lr):
        """Kernel route: run the sharded local SGD, flatten the uploads
        and hand Algorithm 3 to ``fill_aggregate_stacked(backend="pallas")``
        (weight-0 padding rows contribute nothing)."""
        lr = jnp.float32(lr)
        chunks = []
        for keys_a, xb, yb, w in buckets:
            outs = self._train_uploads(master, keys_a, xb, yb, lr)
            self.dispatches += 1
            gp, s = w.shape
            flat = jax.tree.map(
                lambda x: x.reshape((gp * s,) + x.shape[2:]), outs)
            chunks.append((flat, np.repeat(np.asarray(keys_a), s, axis=0),
                           np.asarray(w).reshape(-1)))
        master = fill_aggregate_stacked(master, chunks,
                                        mask_fn=self.api.trained_mask,
                                        backend="pallas", total=1.0)
        self.dispatches += len(chunks)
        return master

    # -- FedAvg paths (train_fedavg delegates via StackedClientBase) ---------

    def train_fedavg_population(self, params_list, keys, client_ids, lr,
                                survivors=None):
        if not params_list:
            return []
        total = self._survivor_total(client_ids, survivors)
        if total == 0.0:               # nobody survived: models untouched
            return list(params_list)
        n = len(params_list)
        pad = self._pad(n)
        plist = list(params_list) + [params_list[-1]] * pad
        klist = [np.asarray(k, np.int32) for k in keys]
        klist = klist + [klist[-1]] * pad
        stacked = self._put_pop_tree(
            jax.tree.map(lambda *xs: jnp.stack(xs), *plist))
        keys_arr = self._put_pop(np.stack(klist))
        lr = jnp.float32(lr)
        if self.cfg.fused:
            buckets = tuple((xb, yb, jnp.asarray(w / total))
                            for xb, yb, w, _ in
                            self._group_train_gather(client_ids, survivors))
            out = self._fused_fedavg(stacked, keys_arr, buckets, lr)
            self.dispatches += 1
            return [jax.tree.map(lambda x: x[i], out) for i in range(n)]
        acc = None
        for xb, yb, w, _ in self._group_train_gather(client_ids, survivors):
            part = self._fedavg_partial(stacked, keys_arr, xb, yb,
                                        jnp.asarray(w / total), lr)
            self.dispatches += 1
            acc = part if acc is None else jax.tree.map(jnp.add, acc, part)
        out = jax.tree.map(lambda a, s: a.astype(s.dtype), acc, stacked)
        return [jax.tree.map(lambda x: x[i], out) for i in range(n)]

    # -- evaluation ----------------------------------------------------------

    def _padded_keys(self, keys):
        klist = [np.asarray(k, np.int32) for k in keys]
        klist = klist + [klist[-1]] * self._pad(len(klist))
        return self._put_pop(np.stack(klist))

    def eval_shared(self, params, keys, client_ids, survivors=None):
        batches = self._test_batches(client_ids)
        masks = self._alive_masks(batches, survivors)
        total = self._alive_total(batches, masks)
        if total == 0:                 # nobody evaluated: pessimistic
            return np.ones(len(keys))
        karr = self._padded_keys(keys)
        if self.cfg.fused:
            counts = self._fused_eval_shared(
                params, karr, tuple((cb.xb, cb.yb, m)
                                    for cb, m in zip(batches, masks)))
            self.dispatches += 1
            return self._rates(counts, total, len(keys))
        wrong = np.zeros(karr.shape[0], np.int64)
        for cb, m in zip(batches, masks):
            counts = self._eval_shared_counts(params, karr,
                                              jnp.asarray(cb.xb),
                                              jnp.asarray(cb.yb),
                                              jnp.asarray(m))
            self.dispatches += 1
            wrong += np.asarray(counts, np.int64)
        return wrong[:len(keys)] / total

    def eval_paired(self, params_list, keys, client_ids, survivors=None):
        batches = self._test_batches(client_ids)
        masks = self._alive_masks(batches, survivors)
        total = self._alive_total(batches, masks)
        if total == 0:                 # nobody evaluated: pessimistic
            return np.ones(len(keys))
        pad = self._pad(len(params_list))
        plist = list(params_list) + [params_list[-1]] * pad
        stacked = self._put_pop_tree(
            jax.tree.map(lambda *xs: jnp.stack(xs), *plist))
        karr = self._padded_keys(keys)
        if self.cfg.fused:
            counts = self._fused_eval_paired(
                stacked, karr, tuple((cb.xb, cb.yb, m)
                                     for cb, m in zip(batches, masks)))
            self.dispatches += 1
            return self._rates(counts, total, len(keys))
        wrong = np.zeros(karr.shape[0], np.int64)
        for cb, m in zip(batches, masks):
            counts = self._eval_paired_counts(stacked, karr,
                                              jnp.asarray(cb.xb),
                                              jnp.asarray(cb.yb),
                                              jnp.asarray(m))
            self.dispatches += 1
            wrong += np.asarray(counts, np.int64)
        return wrong[:len(keys)] / total


from repro.engine import backends as _backends  # noqa: E402

_backends.BACKENDS.setdefault("mesh", MeshBackend)
