"""Real-time client availability simulation (``ClientSimConfig``).

The paper's core claim is that double sampling + weight inheritance keep
the architecture search stable while clients *come and go* — the
defining constraint of mobile federated NAS (Zhu, Zhang & Jin 2020; Xu
et al., DecNAS).  ``ClientSimulator`` turns that into a per-round draw
the engine applies between participant sampling and the strategy:

  * **availability** — each sampled client checks in with probability
    ``availability`` (or its ``availability_trace`` entry, or a
    probability drawn once per client from the compact
    ``availability_dist`` spec — see ``_DIST_STREAM``).  Absent
    clients receive nothing and cost nothing; the round's client groups
    are formed over the available subset only, degrading gracefully all
    the way to empty groups (``core.double_sampling``).
  * **dropout / deadline** — each checked-in client then fails before
    its uploads with probability ``dropout``, and independently misses
    the round when its simulated finish time ``speed × U(0.8, 1.2)``
    exceeds ``round_deadline`` (stragglers carry
    ``straggler_slowdown``× speed, assigned to a fixed
    ``straggler_fraction`` of the population per run).  Both land in
    ``RoundSim.dropped``: downloads already pushed to them are booked on
    the ``CommStats`` wasted ledger, and they contribute to neither
    aggregation nor evaluation.

All draws come from the simulator's own RNG stream (``ClientSimConfig
.seed``), never from the engine's search RNG — so turning the simulation
on cannot shift participant sampling or offspring variation, and the
draw order is fixed on the host, which keeps the survivor sets (and
therefore CommStats) byte-identical across execution backends.  An
inactive config (the default) draws nothing at all.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.engine.types import ClientSimConfig

_EMPTY_IDS = np.empty(0, dtype=np.int64)

# Salt mixed into the simulator's SeedSequence so ClientSimConfig.seed=k
# NEVER yields the same PCG64 stream as the engine's default_rng(k) —
# with the obvious defaults (both seeds 0) the availability draws would
# otherwise replay the search's participant/offspring uniforms verbatim,
# silently correlating who drops with what evolves.
_SIM_STREAM_SALT = 0x5EEDFA11

# Sub-stream tag for the counter-based per-client availability draws
# (``ClientSimConfig.availability_dist``): client ``cid``'s personal
# probability comes from ``default_rng((_SIM_STREAM_SALT, seed,
# _DIST_STREAM, cid))`` — O(1) state for any fleet size, deterministic
# per client no matter which rounds sample it, and disjoint from both
# the search stream and the simulator's own round stream.
_DIST_STREAM = 0xD157


@dataclasses.dataclass(frozen=True)
class RoundSim:
    """One round's availability outcome.

    ``participants`` are the checked-in clients (engine sampling order
    preserved — group sampling permutes them with the *search* RNG, as
    ever).  ``survivors`` is ``None`` when the simulation is inactive
    (the exact legacy path); otherwise the frozenset of client ids that
    complete their uploads.  ``dropped`` lists the participants that
    downloaded but never upload this round."""
    participants: np.ndarray
    survivors: Optional[frozenset]
    dropped: np.ndarray
    n_sampled: int

    @property
    def active(self) -> bool:
        return self.survivors is not None

    @property
    def n_dropped(self) -> int:
        return len(self.dropped)

    @property
    def n_survivors(self) -> int:
        """Surviving participant count (all of them when inactive)."""
        return (len(self.participants) if self.survivors is None
                else len(self.survivors))

    @classmethod
    def inactive(cls, participants: np.ndarray) -> "RoundSim":
        participants = np.asarray(participants)
        return cls(participants, None, _EMPTY_IDS, len(participants))


class ClientSimulator:
    """Per-run simulator state: the sim RNG stream and the fixed
    straggler speed assignment.  Built fresh by every ``FedEngine.run``
    so runs are re-entrant and seed-deterministic."""

    def __init__(self, cfg: ClientSimConfig, num_clients: int):
        self.cfg = cfg
        self.active = cfg.is_active
        self.num_clients = num_clients
        trace = cfg.availability_trace
        if trace is not None and len(trace) != num_clients:
            raise ValueError(
                f"availability_trace has {len(trace)} entries for "
                f"{num_clients} clients")
        self._trace = (np.asarray(trace, dtype=float)
                       if trace is not None else None)
        self.rng = np.random.default_rng((_SIM_STREAM_SALT, cfg.seed))
        # straggler speeds are the only per-client array left, and only
        # when stragglers are actually configured — every other per-client
        # quantity is answered lazily for the sampled ids, so simulator
        # state is O(1) in fleet size on the 10^6-client paths
        self.speed = None
        if self.active and cfg.straggler_fraction > 0.0:
            self.speed = np.ones(num_clients)
            k = int(round(cfg.straggler_fraction * num_clients))
            slow = self.rng.permutation(num_clients)[:k]
            self.speed[slow] = cfg.straggler_slowdown

    def _dist_p(self, cid: int) -> float:
        """Client ``cid``'s fixed check-in probability under
        ``availability_dist``, from its counter-based personal stream."""
        name = self.cfg.availability_dist[0]
        params = self.cfg.availability_dist[1:]
        r = np.random.default_rng(
            (_SIM_STREAM_SALT, self.cfg.seed, _DIST_STREAM, int(cid)))
        if name == "bernoulli":
            return 1.0 if r.random() < params[0] else 0.0
        if name == "uniform":
            lo, hi = params
            return lo + (hi - lo) * r.random()
        return float(r.beta(params[0], params[1]))   # "beta"

    def _avail_p(self, ids: np.ndarray) -> np.ndarray:
        """Per-client P(available) for ``ids`` only — O(len(ids)),
        whatever the fleet size."""
        if self._trace is not None:
            return self._trace[ids]
        if self.cfg.availability_dist is not None:
            return np.asarray([self._dist_p(int(c)) for c in ids])
        return np.full(len(ids), self.cfg.availability)

    def draw_round(self, sampled: np.ndarray) -> RoundSim:
        """Draw this round's availability outcome for the sampled
        participants (order-preserving filter)."""
        sampled = np.asarray(sampled)
        if not self.active:
            return RoundSim.inactive(sampled)
        cfg, rng = self.cfg, self.rng
        avail = sampled[rng.random(len(sampled)) < self._avail_p(sampled)]
        drop = rng.random(len(avail)) < cfg.dropout
        if cfg.round_deadline is not None:
            t = rng.uniform(0.8, 1.2, size=len(avail))
            if self.speed is not None:
                t = self.speed[avail] * t
            drop |= t > cfg.round_deadline
        survivors = frozenset(int(c) for c in avail[~drop])
        return RoundSim(avail, survivors, avail[drop], len(sampled))
