"""Real-time client availability simulation (``ClientSimConfig``).

The paper's core claim is that double sampling + weight inheritance keep
the architecture search stable while clients *come and go* — the
defining constraint of mobile federated NAS (Zhu, Zhang & Jin 2020; Xu
et al., DecNAS).  ``ClientSimulator`` turns that into a per-round draw
the engine applies between participant sampling and the strategy:

  * **availability** — each sampled client checks in with probability
    ``availability`` (or its ``availability_trace`` entry).  Absent
    clients receive nothing and cost nothing; the round's client groups
    are formed over the available subset only, degrading gracefully all
    the way to empty groups (``core.double_sampling``).
  * **dropout / deadline** — each checked-in client then fails before
    its uploads with probability ``dropout``, and independently misses
    the round when its simulated finish time ``speed × U(0.8, 1.2)``
    exceeds ``round_deadline`` (stragglers carry
    ``straggler_slowdown``× speed, assigned to a fixed
    ``straggler_fraction`` of the population per run).  Both land in
    ``RoundSim.dropped``: downloads already pushed to them are booked on
    the ``CommStats`` wasted ledger, and they contribute to neither
    aggregation nor evaluation.

All draws come from the simulator's own RNG stream (``ClientSimConfig
.seed``), never from the engine's search RNG — so turning the simulation
on cannot shift participant sampling or offspring variation, and the
draw order is fixed on the host, which keeps the survivor sets (and
therefore CommStats) byte-identical across execution backends.  An
inactive config (the default) draws nothing at all.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.engine.types import ClientSimConfig

_EMPTY_IDS = np.empty(0, dtype=np.int64)

# Salt mixed into the simulator's SeedSequence so ClientSimConfig.seed=k
# NEVER yields the same PCG64 stream as the engine's default_rng(k) —
# with the obvious defaults (both seeds 0) the availability draws would
# otherwise replay the search's participant/offspring uniforms verbatim,
# silently correlating who drops with what evolves.
_SIM_STREAM_SALT = 0x5EEDFA11


@dataclasses.dataclass(frozen=True)
class RoundSim:
    """One round's availability outcome.

    ``participants`` are the checked-in clients (engine sampling order
    preserved — group sampling permutes them with the *search* RNG, as
    ever).  ``survivors`` is ``None`` when the simulation is inactive
    (the exact legacy path); otherwise the frozenset of client ids that
    complete their uploads.  ``dropped`` lists the participants that
    downloaded but never upload this round."""
    participants: np.ndarray
    survivors: Optional[frozenset]
    dropped: np.ndarray
    n_sampled: int

    @property
    def active(self) -> bool:
        return self.survivors is not None

    @property
    def n_dropped(self) -> int:
        return len(self.dropped)

    @property
    def n_survivors(self) -> int:
        """Surviving participant count (all of them when inactive)."""
        return (len(self.participants) if self.survivors is None
                else len(self.survivors))

    @classmethod
    def inactive(cls, participants: np.ndarray) -> "RoundSim":
        participants = np.asarray(participants)
        return cls(participants, None, _EMPTY_IDS, len(participants))


class ClientSimulator:
    """Per-run simulator state: the sim RNG stream and the fixed
    straggler speed assignment.  Built fresh by every ``FedEngine.run``
    so runs are re-entrant and seed-deterministic."""

    def __init__(self, cfg: ClientSimConfig, num_clients: int):
        self.cfg = cfg
        self.active = cfg.is_active
        trace = cfg.availability_trace
        if trace is not None and len(trace) != num_clients:
            raise ValueError(
                f"availability_trace has {len(trace)} entries for "
                f"{num_clients} clients")
        self.rng = np.random.default_rng((_SIM_STREAM_SALT, cfg.seed))
        self.avail_p = (np.asarray(trace, dtype=float) if trace is not None
                        else np.full(num_clients, cfg.availability))
        self.speed = np.ones(num_clients)
        if self.active and cfg.straggler_fraction > 0.0:
            k = int(round(cfg.straggler_fraction * num_clients))
            slow = self.rng.permutation(num_clients)[:k]
            self.speed[slow] = cfg.straggler_slowdown

    def draw_round(self, sampled: np.ndarray) -> RoundSim:
        """Draw this round's availability outcome for the sampled
        participants (order-preserving filter)."""
        sampled = np.asarray(sampled)
        if not self.active:
            return RoundSim.inactive(sampled)
        cfg, rng = self.cfg, self.rng
        avail = sampled[rng.random(len(sampled)) < self.avail_p[sampled]]
        drop = rng.random(len(avail)) < cfg.dropout
        if cfg.round_deadline is not None:
            t = self.speed[avail] * rng.uniform(0.8, 1.2, size=len(avail))
            drop |= t > cfg.round_deadline
        survivors = frozenset(int(c) for c in avail[~drop])
        return RoundSim(avail, survivors, avail[drop], len(sampled))
