"""FedEngine: one round loop for every federated NAS runtime.

The engine owns what is common to the paper's Algorithms 1/4 and the
offline baseline — participant sampling, the per-round lr schedule,
communication/compute accounting and the typed ``RoundReport`` history —
and delegates the rest to a ``Strategy`` (what happens inside a round) and
an ``ExecutionBackend`` (how client work is dispatched: ``"loop"`` for the
reference per-pair path, ``"vmap"`` for the vectorized one, ``"mesh"``
for the device-mesh-sharded one — see docs/architecture.md).

    engine = FedEngine(api, clients, RunConfig(backend="mesh"))
    result = engine.run()            # EngineResult
    history = result.history()       # legacy dict-of-lists view
"""
from __future__ import annotations

import time
from typing import Callable, Optional, Sequence, Union

import numpy as np

from repro.comm import CodecBackend, make_codec
from repro.core.double_sampling import sample_participants
from repro.core.supernet import SupernetAPI
from repro.data.pipeline import ClientDataset
from repro.engine.availability import ClientSimulator, RoundSim
from repro.engine.backends import ExecutionBackend, make_backend
from repro.engine.strategies import RealTimeNas, Strategy
from repro.engine.types import CommStats, EngineResult, RoundReport, \
    RunConfig
from repro.obs import NULL_TELEMETRY, InstrumentedBackend, Telemetry, attach
from repro.optim import round_decay


class FedEngine:
    """One round loop for every federated NAS runtime.

    Args:
      * ``api`` — the model family's ``SupernetAPI`` (init / loss /
        error-count / trained-mask / flops / payload as functions of a
        choice key).
      * ``clients`` — the ``ClientDataset`` population (pre-batched
        local train/test shards; ``weight`` = n_k for weighted
        averaging).
      * ``cfg`` — a ``RunConfig`` (defaults to ``RunConfig()``); see its
        docstring for every knob and unit.
      * ``strategy`` — what happens inside a round; defaults to
        ``RealTimeNas()`` (paper Algorithm 4).
      * ``backend`` — an execution backend name (``'loop' | 'vmap' |
        'mesh'``, overriding ``cfg.backend``) or an already-built
        ``ExecutionBackend`` instance (e.g. ``MeshBackend(...,
        mesh=make_production_mesh())``).  Unknown names raise here, at
        construction time.
    """

    def __init__(self, api: SupernetAPI, clients: Sequence[ClientDataset],
                 cfg: Optional[RunConfig] = None,
                 strategy: Optional[Strategy] = None,
                 backend: Union[str, ExecutionBackend, None] = None):
        self.api = api
        # indexable client collections (lists, lazy ClientFleet) are kept
        # as-is — list()-ing a million-client fleet would materialize it;
        # plain iterables are drained once
        if hasattr(clients, "__getitem__") and hasattr(clients, "__len__"):
            self.clients = clients
        else:
            self.clients = list(clients)
        self.cfg = cfg or RunConfig()
        self.strategy = strategy or RealTimeNas()
        if backend is None or isinstance(backend, str):
            self.backend = make_backend(backend or self.cfg.backend,
                                        api, self.clients, self.cfg)
        else:
            self.backend = backend
        # payload codecs (repro.comm): strategies read these for wire-byte
        # accounting; lossy codecs additionally wrap the execution backend
        # so encode->decode happens around every client train/eval
        self.uplink_codec = make_codec(self.cfg.uplink_codec)
        self.downlink_codec = make_codec(self.cfg.downlink_codec)
        if not (self.uplink_codec.is_identity
                and self.downlink_codec.is_identity):
            self.backend = CodecBackend(self.backend, self.uplink_codec,
                                        self.downlink_codec)
        # telemetry (repro.obs): only when RunConfig.telemetry is enabled
        # does the engine build a real Telemetry and wrap the backend —
        # the InstrumentedBackend goes OUTERMOST so its fill_train/eval
        # spans cover codec encode/decode, which nest beneath them.
        # Disabled runs keep the exact pre-subsystem object graph
        # (everything sees the shared no-op NULL_TELEMETRY).
        tcfg = self.cfg.telemetry
        if tcfg is not None and tcfg.enabled:
            self.telemetry = Telemetry(tcfg)
            attach(self.backend, self.telemetry)
            self.backend = InstrumentedBackend(self.backend, self.telemetry)
        else:
            self.telemetry = NULL_TELEMETRY
        self.rng = np.random.default_rng(self.cfg.seed)
        self.stats = CommStats()
        self.reports: list[RoundReport] = []
        # client-availability simulation (repro.engine.availability) —
        # constructed here so a bad availability_trace fails at engine
        # build time, and rebuilt per run() for re-entrancy
        self.sim = ClientSimulator(self.cfg.client_sim, len(self.clients))
        self.round_ctx: Optional[RoundSim] = None

    def run(self, callback: Optional[Callable[[int, RoundReport], None]]
            = None) -> EngineResult:
        """Run ``cfg.generations`` federated rounds and return an
        ``EngineResult`` (typed ``RoundReport`` history + ``CommStats``
        totals + strategy extras).  ``callback(gen, report)`` fires after
        every round.  Re-entrant: repeated calls reset all run state and
        reproduce the same seed-deterministic trajectory."""
        cfg = self.cfg
        # fresh run state so repeated run() calls are independent and
        # seed-reproducible (the legacy rt_enas.run was a pure function)
        self.rng = np.random.default_rng(cfg.seed)
        self.stats = CommStats()
        self.reports = []
        self.backend.dispatches = 0
        reset = getattr(self.backend, "reset", None)
        if reset is not None:        # CodecBackend: drop EF residuals
            reset()
        self.sim = ClientSimulator(cfg.client_sim, len(self.clients))
        self.strategy.setup(self)
        tel = self.telemetry
        tel.start_run(self)
        with tel.run_capture():   # jax.profiler.trace when configured
            # perf_counter, not time.time(): wall-clock is not monotonic,
            # an NTP step mid-run would corrupt the recorded round_s
            t0 = t_prev = time.perf_counter()
            for gen in range(1, cfg.generations + 1):
                lr = float(round_decay(cfg.lr0, cfg.lr_decay, gen - 1))
                with tel.span("sample"):
                    sampled = sample_participants(self.rng,
                                                  len(self.clients),
                                                  cfg.participation)
                # availability / dropout draw (sim RNG only — the search
                # RNG stream above is untouched by the simulation)
                with tel.span("availability"):
                    ctx = self.sim.draw_round(sampled)
                self.round_ctx = ctx
                report = self.strategy.round(self, gen, ctx.participants,
                                             lr)
                report.down_gb = self.stats.down_bytes / 1e9
                report.up_gb = self.stats.up_bytes / 1e9
                report.train_passes = self.stats.client_train_passes
                if ctx.active:
                    report.n_sampled = ctx.n_sampled
                    report.n_available = len(ctx.participants)
                    report.n_dropped = ctx.n_dropped
                    report.n_survivors = ctx.n_survivors
                    report.wasted_down_gb = \
                        self.stats.wasted_down_bytes / 1e9
                now = time.perf_counter()
                report.wall_s = now - t0      # cumulative since run()
                report.round_s = now - t_prev  # this round's delta
                t_prev = now
                self.reports.append(report)
                tel.end_round(gen, report.round_s, self)
                if callback:
                    callback(gen, report)
        # a stale RoundSim must not leak into strategies driven manually
        # on this engine afterwards (they fall back to an inactive ctx)
        self.round_ctx = None
        return EngineResult(reports=self.reports, stats=self.stats,
                            extras=self.strategy.extras(self),
                            telemetry=tel.result(self))
