"""Synthetic-but-learnable datasets.

CIFAR-10 is not available in the offline container (DESIGN.md Section 8), so
the federated experiments use a class-conditional image mixture with the
same tensor shapes (32x32x3, 10 classes): each class owns a smooth random
prototype field; samples are prototype + noise.  Difficulty is controlled
by the signal/noise ratio, giving non-trivial but CPU-learnable tasks whose
*relative* comparisons (RT vs offline, Pareto shape) mirror the paper's.

LM streams for the transformer smoke/integration tests follow a noisy
first-order Markov chain, so next-token prediction has learnable structure.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def make_classification(seed: int, n: int, image: int = 32, classes: int = 10,
                        channels: int = 3, signal: float = 1.0,
                        noise: float = 1.0) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    # smooth prototypes: low-res random fields upsampled (so conv nets with
    # small receptive fields can pick up class structure)
    low = rng.normal(size=(classes, 4, 4, channels))
    reps = image // 4
    protos = np.repeat(np.repeat(low, reps, axis=1), reps, axis=2)
    y = rng.integers(0, classes, size=n)
    x = protos[y] * signal + rng.normal(size=(n, image, image, channels)) * noise
    return x.astype(np.float32), y.astype(np.int32)


class VirtualClassification:
    """Materialization-free class-conditional image source.

    Same prototype-plus-noise structure as ``make_classification`` (the
    class prototypes come from the identical ``default_rng(seed)``
    draws), but sample ``i``'s label and noise come from a per-index
    counter-based stream ``default_rng((seed, i))`` — so ``take(idx)``
    produces ANY subset of a nominal ``n``-sample dataset in O(len(idx))
    time and memory, and a 10^6-client fleet's "dataset" never exists as
    a dense array.  NOT sample-for-sample identical to
    ``make_classification`` (which draws all labels, then all noise,
    from one sequential stream — an order a lazy source cannot replay
    per index); parity-pinned runs use the eager dataset, the scale
    sweeps use this one.

    Plugs into ``repro.data.pipeline.ClientFleet`` via ``take``."""

    def __init__(self, seed: int, n: int, image: int = 32,
                 classes: int = 10, channels: int = 3,
                 signal: float = 1.0, noise: float = 1.0):
        rng = np.random.default_rng(seed)
        low = rng.normal(size=(classes, 4, 4, channels))
        reps = image // 4
        self.protos = np.repeat(np.repeat(low, reps, axis=1), reps, axis=2)
        self.seed = seed
        self.n = n
        self.image = image
        self.classes = classes
        self.channels = channels
        self.signal = signal
        self.noise = noise

    def __len__(self) -> int:
        return self.n

    def take(self, indices) -> Tuple[np.ndarray, np.ndarray]:
        """Materialize the samples at ``indices`` (sorted or not)."""
        idx = np.asarray(indices, dtype=np.int64)
        if idx.size and (idx.min() < 0 or idx.max() >= self.n):
            raise IndexError(f"sample indices out of range [0, {self.n})")
        shape = (self.image, self.image, self.channels)
        x = np.empty((len(idx),) + shape, np.float32)
        y = np.empty(len(idx), np.int32)
        for row, i in enumerate(idx):
            r = np.random.default_rng((self.seed, int(i)))
            yi = int(r.integers(0, self.classes))
            y[row] = yi
            x[row] = (self.protos[yi] * self.signal
                      + r.normal(size=shape) * self.noise)
        return x, y


def make_lm_stream(seed: int, n_seqs: int, seq_len: int, vocab: int,
                   order_noise: float = 0.1) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    nxt = rng.integers(0, vocab, size=vocab)          # deterministic successor
    toks = np.empty((n_seqs, seq_len + 1), np.int64)
    toks[:, 0] = rng.integers(0, vocab, size=n_seqs)
    for t in range(seq_len):
        follow = nxt[toks[:, t]]
        rand = rng.integers(0, vocab, size=n_seqs)
        use_rand = rng.random(n_seqs) < order_noise
        toks[:, t + 1] = np.where(use_rand, rand, follow)
    return toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)
