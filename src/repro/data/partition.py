"""Federated data partitioners (paper Section IV.C).

IID: even random split, no overlap.  non-IID: each client holds images from
exactly ``classes_per_client`` classes (paper uses 5 of 10).  A Dirichlet
partitioner is included as the standard harder benchmark.
"""
from __future__ import annotations

from typing import List

import numpy as np


def partition_iid(seed: int, n: int, num_clients: int) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    return [np.sort(s) for s in np.array_split(perm, num_clients)]


def partition_label(seed: int, labels: np.ndarray, num_clients: int,
                    classes_per_client: int = 5) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    # assign each client a set of classes, round-robin so coverage is even
    client_classes = []
    pool = []
    for c in range(num_clients):
        if len(pool) < classes_per_client:
            pool.extend(rng.permutation(classes).tolist())
        client_classes.append([pool.pop() for _ in range(classes_per_client)])
    # shards of each class split among the clients holding that class;
    # classes no client holds (possible when k*cpc < #classes) are dropped —
    # the "each client sees exactly cpc classes" semantics of the paper win
    # over full data coverage in that degenerate regime.
    holders = {c: [i for i, cc in enumerate(client_classes) if c in cc]
               for c in classes}
    out: List[List[int]] = [[] for _ in range(num_clients)]
    for c in classes:
        if not holders[c]:
            continue
        idx = np.where(labels == c)[0]
        idx = rng.permutation(idx)
        hs = holders[c]
        for h, shard in zip(hs, np.array_split(idx, len(hs))):
            out[h].extend(shard.tolist())
    return [np.sort(np.asarray(s, dtype=np.int64)) for s in out]


def partition_dirichlet(seed: int, labels: np.ndarray, num_clients: int,
                        alpha: float = 0.5) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    out: List[List[int]] = [[] for _ in range(num_clients)]
    for c in np.unique(labels):
        idx = rng.permutation(np.where(labels == c)[0])
        probs = rng.dirichlet([alpha] * num_clients)
        cuts = (np.cumsum(probs)[:-1] * len(idx)).astype(int)
        for h, shard in enumerate(np.split(idx, cuts)):
            out[h].extend(shard.tolist())
    return [np.sort(np.asarray(s, dtype=np.int64)) for s in out]
