"""Federated data partitioners (paper Section IV.C) — lazy, index-space.

IID: even random split, no overlap.  non-IID: each client holds images from
exactly ``classes_per_client`` classes (paper uses 5 of 10).  A Dirichlet
partitioner is included as the standard harder benchmark.

Every partitioner returns a lazy ``Partition`` instead of a list of
per-client index arrays: construction stores only O(dataset) permutations
plus O(num_clients) integer quota/cut vectors, and a client's shard is
assembled on demand by ``indices_for(client_id)`` (``partition[cid]`` /
iteration work too, so existing ``make_clients``-style callers are
unchanged).  That makes ``num_clients`` a cheap axis: a 10^6-client
partition costs megabytes of cut vectors, not 10^6 Python lists, and the
paper's cross-device regime — sample a handful of participants out of a
huge fleet each round — only ever materializes the sampled shards
(``repro.data.pipeline.ClientFleet``).

The lazy shards are **bit-identical** to the historical eager outputs for
the same ``(seed, ...)`` arguments: each partitioner consumes its RNG
stream in exactly the order the eager implementation did, and slicing
reproduces ``np.array_split`` / ``np.split`` semantics cut for cut
(pinned by ``tests/test_data.py``).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np


class Partition(Sequence):
    """Lazy index-space partition of ``range(n)`` into ``num_clients``
    shards.

    Sequence protocol: ``len(p)`` is the client count, ``p[cid]`` /
    ``p.indices_for(cid)`` materializes client ``cid``'s sorted int64
    sample-index array, iteration yields every shard in order.
    ``shard_sizes()`` answers all shard lengths from the stored cut
    vectors without materializing anything; ``nbytes`` is the host
    memory the partition state actually holds."""

    num_clients: int

    def indices_for(self, client_id: int) -> np.ndarray:
        """Client ``client_id``'s sorted sample indices (materialized on
        demand, O(shard size))."""
        raise NotImplementedError

    def shard_sizes(self) -> np.ndarray:
        """(num_clients,) int64 shard lengths, computed from the cut
        vectors — O(num_clients), no shard is materialized."""
        raise NotImplementedError

    @property
    def nbytes(self) -> int:
        """Host bytes held by the partition's internal arrays."""
        raise NotImplementedError

    def materialize(self) -> List[np.ndarray]:
        """Every shard as an eager list (the historical return type)."""
        return [self.indices_for(i) for i in range(self.num_clients)]

    # -- sequence protocol ---------------------------------------------------

    def __len__(self) -> int:
        return self.num_clients

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self.indices_for(j)
                    for j in range(*i.indices(self.num_clients))]
        i = int(i)
        if i < 0:
            i += self.num_clients
        if not 0 <= i < self.num_clients:
            raise IndexError(f"client {i} out of range "
                             f"(num_clients={self.num_clients})")
        return self.indices_for(i)

    def __iter__(self):
        for i in range(self.num_clients):
            yield self.indices_for(i)


def _split_cuts(n: int, parts: int) -> np.ndarray:
    """``np.array_split`` cut points: (parts + 1,) int64 offsets where
    the first ``n % parts`` parts get the extra element."""
    base, extra = divmod(n, parts)
    sizes = np.full(parts, base, dtype=np.int64)
    sizes[:extra] += 1
    return np.concatenate(([0], np.cumsum(sizes)))


class IidPartition(Partition):
    """Even random split: one stored permutation + one cut vector."""

    def __init__(self, perm: np.ndarray, cuts: np.ndarray):
        self.num_clients = len(cuts) - 1
        self._perm = perm
        self._cuts = cuts

    def indices_for(self, client_id: int) -> np.ndarray:
        a, b = self._cuts[client_id], self._cuts[client_id + 1]
        return np.sort(self._perm[a:b])

    def shard_sizes(self) -> np.ndarray:
        return np.diff(self._cuts)

    @property
    def nbytes(self) -> int:
        return self._perm.nbytes + self._cuts.nbytes


class LabelPartition(Partition):
    """Exactly-``cpc``-classes shards from the balanced quota deal: each
    client stores its ``cpc`` (class, holder-slot) assignments; each held
    class stores one permutation of its sample indices, split
    ``array_split``-style over its holders."""

    def __init__(self, num_clients: int, class_pos: np.ndarray,
                 slots: np.ndarray, holder_counts: np.ndarray,
                 members: List[Optional[np.ndarray]]):
        self.num_clients = num_clients
        self._class_pos = class_pos          # (k, cpc) class index
        self._slots = slots                  # (k, cpc) position among holders
        self._holder_counts = holder_counts  # (C,) holders per class
        self._members = members              # per class: permuted sample idx

    def indices_for(self, client_id: int) -> np.ndarray:
        parts = []
        for ci, slot in zip(self._class_pos[client_id],
                            self._slots[client_id]):
            m = self._members[ci]
            base, extra = divmod(len(m), int(self._holder_counts[ci]))
            start = slot * base + min(slot, extra)
            parts.append(m[start:start + base + (1 if slot < extra else 0)])
        return np.sort(np.concatenate(parts).astype(np.int64))

    def shard_sizes(self) -> np.ndarray:
        lens = np.asarray([0 if m is None else len(m)
                           for m in self._members], np.int64)
        holders = np.maximum(self._holder_counts, 1)
        base, extra = lens // holders, lens % holders
        cp = self._class_pos
        return (base[cp] + (self._slots < extra[cp])).sum(axis=1)

    @property
    def nbytes(self) -> int:
        return (self._class_pos.nbytes + self._slots.nbytes
                + self._holder_counts.nbytes
                + sum(m.nbytes for m in self._members if m is not None))


class DirichletPartition(Partition):
    """Dirichlet(alpha) label shards: per class, one permutation of its
    sample indices plus the (num_clients + 1,) proportional cut vector."""

    def __init__(self, num_clients: int,
                 members: List[np.ndarray], cuts: List[np.ndarray]):
        self.num_clients = num_clients
        self._members = members   # per class: permuted sample idx
        self._cuts = cuts         # per class: (k + 1,) int64 offsets

    def indices_for(self, client_id: int) -> np.ndarray:
        parts = [m[c[client_id]:c[client_id + 1]]
                 for m, c in zip(self._members, self._cuts)]
        return np.sort(np.concatenate(parts).astype(np.int64))

    def shard_sizes(self) -> np.ndarray:
        sizes = np.zeros(self.num_clients, np.int64)
        for c in self._cuts:
            sizes += np.diff(c)
        return sizes

    @property
    def nbytes(self) -> int:
        return (sum(m.nbytes for m in self._members)
                + sum(c.nbytes for c in self._cuts))


def partition_iid(seed: int, n: int, num_clients: int) -> IidPartition:
    if num_clients < 1:
        raise ValueError(f"num_clients must be >= 1, got {num_clients}")
    rng = np.random.default_rng(seed)
    return IidPartition(rng.permutation(n), _split_cuts(n, num_clients))


def partition_label(seed: int, labels: np.ndarray, num_clients: int,
                    classes_per_client: int = 5) -> LabelPartition:
    """Non-IID label partition: every client holds data from exactly
    ``classes_per_client`` DISTINCT classes (the paper uses 5 of 10).

    Class sets are assigned by a balanced greedy deal: each class starts
    with a quota of ``floor/ceil(k*cpc / C)`` holder slots (the
    remainder spread over a random subset) and each client takes the
    ``cpc`` classes with the largest remaining quota, random tiebreak.
    Taking the maxima keeps the quotas balanced, which guarantees the
    deal never runs out of distinct classes for a client and — whenever
    ``k*cpc >= C`` — that every class ends up with at least one holder,
    i.e. full data coverage.  (The previous stack-based dealer could
    hand a client the same class twice and strand stale classes when
    ``cpc`` did not divide ``C``.)  Only when ``k*cpc < C`` do some
    classes go unheld and their data dropped — the "each client sees
    exactly cpc classes" semantics of the paper win over full coverage
    in that degenerate regime.
    """
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    classes = np.unique(labels)
    n_classes = len(classes)
    cpc = classes_per_client
    if not 1 <= cpc <= n_classes:
        raise ValueError(f"classes_per_client must be in [1, {n_classes}] "
                         f"(distinct classes available), got {cpc}")
    base, extra = divmod(num_clients * cpc, n_classes)
    quota = np.full(n_classes, base, dtype=np.int64)
    quota[rng.permutation(n_classes)[:extra]] += 1
    class_pos = np.empty((num_clients, cpc), np.int64)
    slots = np.empty((num_clients, cpc), np.int64)
    holder_counts = np.zeros(n_classes, np.int64)
    for i in range(num_clients):
        # cpc largest remaining quotas, ties broken at random
        pick = np.lexsort((rng.random(n_classes), -quota))[:cpc]
        quota[pick] -= 1
        class_pos[i] = pick
        slots[i] = holder_counts[pick]   # holders accrue in client order
        holder_counts[pick] += 1
    members: List[Optional[np.ndarray]] = []
    for ci, c in enumerate(classes):
        if holder_counts[ci] == 0:
            members.append(None)
            continue
        idx = np.where(labels == c)[0]
        if len(idx) < holder_counts[ci]:
            # an empty split would silently break the exactly-cpc
            # guarantee for some holder — fail loudly instead
            raise ValueError(
                f"class {c} has {len(idx)} samples for "
                f"{int(holder_counts[ci])} holders; reduce num_clients or "
                f"classes_per_client (every holder needs at least one "
                f"sample)")
        members.append(rng.permutation(idx))
    return LabelPartition(num_clients, class_pos, slots, holder_counts,
                          members)


def partition_dirichlet(seed: int, labels: np.ndarray, num_clients: int,
                        alpha: float = 0.5, min_samples: int = 0,
                        resample: int = 20) -> DirichletPartition:
    """Dirichlet(alpha) label partition.

    Heavy-tailed draws (small ``alpha``, many clients) can hand a client
    ZERO samples, which used to surface only much later as a confusing
    ``batched``/stack failure.  ``min_samples > 0`` guards against that:
    the partition is redrawn (continuing the same RNG stream, so the
    guard stays deterministic) up to ``resample`` times until every
    shard holds at least ``min_samples`` indices, then fails loudly with
    the offending shard sizes.  The default ``min_samples=0`` keeps the
    historical behavior — and the historical RNG consumption — bit for
    bit."""
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    classes = np.unique(labels)
    class_idx = [np.where(labels == c)[0] for c in classes]
    part = None
    for _ in range(max(1, int(resample))):
        members, cuts = [], []
        for idx in class_idx:
            idx = rng.permutation(idx)
            probs = rng.dirichlet([alpha] * num_clients)
            inner = (np.cumsum(probs)[:-1] * len(idx)).astype(np.int64)
            members.append(idx)
            cuts.append(np.concatenate(([0], inner, [len(idx)])))
        part = DirichletPartition(num_clients, members, cuts)
        if min_samples <= 0:
            return part
        if int(part.shard_sizes().min()) >= min_samples:
            return part
    sizes = part.shard_sizes()
    starved = np.flatnonzero(sizes < min_samples)
    raise ValueError(
        f"partition_dirichlet(alpha={alpha}) could not give every one of "
        f"{num_clients} clients min_samples={min_samples} within "
        f"{resample} redraws over {len(labels)} samples: clients "
        f"{starved[:8].tolist()}{'...' if len(starved) > 8 else ''} hold "
        f"{sizes[starved[:8]].tolist()} — use fewer clients, a larger "
        f"alpha, or more data")
