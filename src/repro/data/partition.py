"""Federated data partitioners (paper Section IV.C).

IID: even random split, no overlap.  non-IID: each client holds images from
exactly ``classes_per_client`` classes (paper uses 5 of 10).  A Dirichlet
partitioner is included as the standard harder benchmark.
"""
from __future__ import annotations

from typing import List

import numpy as np


def partition_iid(seed: int, n: int, num_clients: int) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    return [np.sort(s) for s in np.array_split(perm, num_clients)]


def partition_label(seed: int, labels: np.ndarray, num_clients: int,
                    classes_per_client: int = 5) -> List[np.ndarray]:
    """Non-IID label partition: every client holds data from exactly
    ``classes_per_client`` DISTINCT classes (the paper uses 5 of 10).

    Class sets are assigned by a balanced greedy deal: each class starts
    with a quota of ``floor/ceil(k*cpc / C)`` holder slots (the
    remainder spread over a random subset) and each client takes the
    ``cpc`` classes with the largest remaining quota, random tiebreak.
    Taking the maxima keeps the quotas balanced, which guarantees the
    deal never runs out of distinct classes for a client and — whenever
    ``k*cpc >= C`` — that every class ends up with at least one holder,
    i.e. full data coverage.  (The previous stack-based dealer could
    hand a client the same class twice and strand stale classes when
    ``cpc`` did not divide ``C``.)  Only when ``k*cpc < C`` do some
    classes go unheld and their data dropped — the "each client sees
    exactly cpc classes" semantics of the paper win over full coverage
    in that degenerate regime.
    """
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    n_classes = len(classes)
    cpc = classes_per_client
    if not 1 <= cpc <= n_classes:
        raise ValueError(f"classes_per_client must be in [1, {n_classes}] "
                         f"(distinct classes available), got {cpc}")
    base, extra = divmod(num_clients * cpc, n_classes)
    quota = np.full(n_classes, base, dtype=np.int64)
    quota[rng.permutation(n_classes)[:extra]] += 1
    client_classes = []
    for _ in range(num_clients):
        # cpc largest remaining quotas, ties broken at random
        pick = np.lexsort((rng.random(n_classes), -quota))[:cpc]
        quota[pick] -= 1
        client_classes.append(set(classes[pick].tolist()))
    holders = {c: [i for i, cc in enumerate(client_classes) if c in cc]
               for c in classes}
    out: List[List[int]] = [[] for _ in range(num_clients)]
    for c in classes:
        if not holders[c]:
            continue
        idx = np.where(labels == c)[0]
        hs = holders[c]
        if len(idx) < len(hs):
            # an empty split would silently break the exactly-cpc
            # guarantee for some holder — fail loudly instead
            raise ValueError(
                f"class {c} has {len(idx)} samples for {len(hs)} holders; "
                f"reduce num_clients or classes_per_client (every holder "
                f"needs at least one sample)")
        idx = rng.permutation(idx)
        for h, shard in zip(hs, np.array_split(idx, len(hs))):
            out[h].extend(shard.tolist())
    return [np.sort(np.asarray(s, dtype=np.int64)) for s in out]


def partition_dirichlet(seed: int, labels: np.ndarray, num_clients: int,
                        alpha: float = 0.5) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    out: List[List[int]] = [[] for _ in range(num_clients)]
    for c in np.unique(labels):
        idx = rng.permutation(np.where(labels == c)[0])
        probs = rng.dirichlet([alpha] * num_clients)
        cuts = (np.cumsum(probs)[:-1] * len(idx)).astype(int)
        for h, shard in enumerate(np.split(idx, cuts)):
            out[h].extend(shard.tolist())
    return [np.sort(np.asarray(s, dtype=np.int64)) for s in out]
