from repro.data.partition import partition_dirichlet, partition_iid, partition_label
from repro.data.pipeline import ClientDataset, batched, global_batches, make_clients
from repro.data.synthetic import make_classification, make_lm_stream

__all__ = [
    "partition_dirichlet", "partition_iid", "partition_label",
    "ClientDataset", "batched", "global_batches", "make_clients",
    "make_classification", "make_lm_stream",
]
