from repro.data.partition import (
    DirichletPartition, IidPartition, LabelPartition, Partition,
    partition_dirichlet, partition_iid, partition_label,
)
from repro.data.pipeline import (
    ArraySource, ClientDataset, ClientFleet, batched, global_batches,
    make_clients, make_fleet,
)
from repro.data.synthetic import (
    VirtualClassification, make_classification, make_lm_stream,
)

__all__ = [
    "Partition", "IidPartition", "LabelPartition", "DirichletPartition",
    "partition_dirichlet", "partition_iid", "partition_label",
    "ArraySource", "ClientDataset", "ClientFleet", "batched",
    "global_batches", "make_clients", "make_fleet",
    "VirtualClassification", "make_classification", "make_lm_stream",
]
