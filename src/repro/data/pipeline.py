"""Batching pipeline: shapes client shards into (num_batches, B, ...) arrays
consumable by scan-based local training, plus an infinite global-batch
iterator for the launcher's (non-federated) training path."""
from __future__ import annotations

from typing import Dict, Iterator, List, Tuple

import numpy as np


def batched(x: np.ndarray, y: np.ndarray, batch: int, seed: int = 0
            ) -> Tuple[np.ndarray, np.ndarray]:
    """Shuffle and reshape to (nb, batch, ...); drops the ragged tail."""
    rng = np.random.default_rng(seed)
    n = (len(x) // batch) * batch
    if n == 0:
        raise ValueError(f"shard of {len(x)} < batch {batch}")
    perm = rng.permutation(len(x))[:n]
    xb = x[perm].reshape((n // batch, batch) + x.shape[1:])
    yb = y[perm].reshape((n // batch, batch) + y.shape[1:])
    return xb, yb


class ClientDataset:
    """One client's local train/test shards, pre-batched for lax.scan."""

    def __init__(self, cid: int, x: np.ndarray, y: np.ndarray,
                 batch: int, test_batch: int, test_frac: float = 0.2,
                 seed: int = 0):
        rng = np.random.default_rng(seed + cid)
        perm = rng.permutation(len(x))
        n_test = max(test_batch, int(len(x) * test_frac))
        n_test = (n_test // test_batch) * test_batch or test_batch
        te, tr = perm[:n_test], perm[n_test:]
        self.cid = cid
        self.train = batched(x[tr], y[tr], batch, seed=seed + cid)
        self.test = batched(x[te], y[te], test_batch, seed=seed + cid + 7)
        self.n_train = len(tr)

    @property
    def weight(self) -> float:
        return float(self.n_train)


def make_clients(x: np.ndarray, y: np.ndarray, shards: List[np.ndarray],
                 batch: int, test_batch: int, seed: int = 0
                 ) -> List[ClientDataset]:
    return [ClientDataset(i, x[s], y[s], batch, test_batch, seed=seed)
            for i, s in enumerate(shards)]


def global_batches(x: np.ndarray, y: np.ndarray, batch: int, seed: int = 0
                   ) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    n = len(x)
    while True:
        perm = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            s = perm[i:i + batch]
            yield {"x": x[s], "y": y[s]}
