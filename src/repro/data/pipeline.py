"""Batching pipeline: shapes client shards into (num_batches, B, ...) arrays
consumable by scan-based local training, plus ``ClientBatch`` stacking for
the vectorized (vmap) execution backend, the lazy ``ClientFleet`` (clients
materialized on demand from an index-space ``Partition``) and an infinite
global-batch iterator for the launcher's (non-federated) training path."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Sequence, Tuple

import numpy as np


def batched(x: np.ndarray, y: np.ndarray, batch: int, seed: int = 0
            ) -> Tuple[np.ndarray, np.ndarray]:
    """Shuffle and reshape to (nb, batch, ...); drops the ragged tail."""
    rng = np.random.default_rng(seed)
    n = (len(x) // batch) * batch
    if n == 0:
        raise ValueError(f"shard of {len(x)} < batch {batch}")
    perm = rng.permutation(len(x))[:n]
    xb = x[perm].reshape((n // batch, batch) + x.shape[1:])
    yb = y[perm].reshape((n // batch, batch) + y.shape[1:])
    return xb, yb


class ClientDataset:
    """One client's local train/test shards, pre-batched for lax.scan."""

    def __init__(self, cid: int, x: np.ndarray, y: np.ndarray,
                 batch: int, test_batch: int, test_frac: float = 0.2,
                 seed: int = 0):
        rng = np.random.default_rng(seed + cid)
        perm = rng.permutation(len(x))
        n_test = max(test_batch, int(len(x) * test_frac))
        n_test = (n_test // test_batch) * test_batch or test_batch
        te, tr = perm[:n_test], perm[n_test:]
        self.cid = cid
        self.train = batched(x[tr], y[tr], batch, seed=seed + cid)
        self.test = batched(x[te], y[te], test_batch, seed=seed + cid + 7)
        self.n_train = len(tr)

    @property
    def weight(self) -> float:
        return float(self.n_train)


@dataclasses.dataclass
class ClientBatch:
    """A group of client shards stacked along a leading axis so one
    jitted/vmapped dispatch can run every (individual, client) local update
    or (key, client) evaluation at once.

    ``xb``/``yb`` have shape (P, num_batches, B, ...) where P is the number
    of stacked shards.  Stacking requires uniform shard shapes; callers
    bucket ragged client sets with ``shape_buckets`` first.
    """
    xb: np.ndarray
    yb: np.ndarray
    weights: np.ndarray      # (P,) float32 — n_k for training-weighted avg
    client_ids: np.ndarray   # (P,) int

    @property
    def num_shards(self) -> int:
        return self.xb.shape[0]

    @property
    def samples_per_shard(self) -> int:
        return self.xb.shape[1] * self.xb.shape[2]

    @classmethod
    def stack(cls, clients: Sequence["ClientDataset"],
              split: str = "train") -> "ClientBatch":
        if not clients:
            raise ValueError("cannot stack an empty client group")
        if split not in ("train", "test"):
            raise ValueError(f"split must be 'train' or 'test', got {split!r}")
        shards = [(c.train if split == "train" else c.test) for c in clients]
        shapes = {s[0].shape for s in shards}
        if len(shapes) > 1:
            raise ValueError(
                f"ragged {split} shards {sorted(shapes)}; bucket clients by "
                "shape (shape_buckets) before stacking")
        return cls(
            xb=np.stack([np.asarray(s[0]) for s in shards]),
            yb=np.stack([np.asarray(s[1]) for s in shards]),
            weights=np.asarray([c.weight for c in clients], np.float32),
            client_ids=np.asarray([c.cid for c in clients], np.int64))


def shape_buckets(shapes: Sequence[tuple]) -> List[List[int]]:
    """Group indices by identical shape, preserving first-seen order (and
    the original order within a bucket) so vectorized execution stays
    deterministic."""
    order: Dict[tuple, List[int]] = {}
    for i, s in enumerate(shapes):
        order.setdefault(tuple(s), []).append(i)
    return list(order.values())


def make_clients(x: np.ndarray, y: np.ndarray, shards: List[np.ndarray],
                 batch: int, test_batch: int, seed: int = 0
                 ) -> List[ClientDataset]:
    return [ClientDataset(i, x[s], y[s], batch, test_batch, seed=seed)
            for i, s in enumerate(shards)]


class ArraySource:
    """In-memory sample source for ``ClientFleet``: any object with
    ``take(indices) -> (x, y)`` works (see
    ``repro.data.synthetic.VirtualClassification`` for the
    materialization-free variant)."""

    def __init__(self, x: np.ndarray, y: np.ndarray):
        self.x = x
        self.y = y

    def __len__(self) -> int:
        return len(self.x)

    def take(self, indices: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        return self.x[indices], self.y[indices]


class ClientFleet(Sequence[ClientDataset]):
    """Lazy ``ClientDataset`` population over (sample source, lazy
    partition).

    ``fleet[cid]`` materializes client ``cid`` on first access —
    ``ClientDataset(cid, *source.take(partition[cid]), ...)``, exactly
    what ``make_clients`` builds eagerly, so a fleet over the same
    arrays/shards is bit-identical client for client — and keeps the
    ``cache_size`` most recently used clients alive (true LRU: a hit
    refreshes recency).  Anything that indexes a client list (the
    engine, every execution backend) works unchanged, but only the
    clients a round actually samples ever exist: host memory scales
    with participation x cache depth, never with ``len(fleet)``.

    ``materialized`` counts lifetime cache misses (client builds),
    ``hits`` lifetime cache hits, and ``cached`` the currently-live
    entries — the scale regression tests assert against the first and
    last; the telemetry round gauges (``repro.obs``) report all
    three."""

    def __init__(self, source, partition, batch: int, test_batch: int,
                 seed: int = 0, cache_size: int = 128):
        self.source = source
        self.partition = partition
        self.batch = batch
        self.test_batch = test_batch
        self.seed = seed
        self.cache_size = max(1, int(cache_size))
        self.materialized = 0         # lifetime client builds (cache misses)
        self.hits = 0                 # lifetime cache hits
        self._cache: Dict[int, ClientDataset] = {}

    @property
    def cached(self) -> int:
        return len(self._cache)

    def __len__(self) -> int:
        return len(self.partition)

    def __getitem__(self, cid):
        if isinstance(cid, slice):
            return [self[i] for i in range(*cid.indices(len(self)))]
        cid = int(cid)
        if cid < 0:
            cid += len(self)
        if not 0 <= cid < len(self):
            raise IndexError(f"client {cid} out of range "
                             f"(fleet of {len(self)})")
        cache = self._cache
        if cid in cache:
            cache[cid] = cache.pop(cid)      # refresh recency (true LRU)
            self.hits += 1
        else:
            if len(cache) >= self.cache_size:
                cache.pop(next(iter(cache)))  # evict least-recently-used
            x, y = self.source.take(self.partition[cid])
            cache[cid] = ClientDataset(cid, x, y, self.batch,
                                       self.test_batch, seed=self.seed)
            self.materialized += 1
        return cache[cid]

    def __iter__(self) -> Iterator[ClientDataset]:
        for i in range(len(self)):
            yield self[i]


def make_fleet(x: np.ndarray, y: np.ndarray, shards, batch: int,
               test_batch: int, seed: int = 0,
               cache_size: int = 128) -> ClientFleet:
    """``make_clients``, lazily: same per-client datasets (bit for bit),
    materialized on demand with an LRU of ``cache_size`` clients."""
    return ClientFleet(ArraySource(np.asarray(x), np.asarray(y)), shards,
                       batch, test_batch, seed=seed, cache_size=cache_size)


def global_batches(x: np.ndarray, y: np.ndarray, batch: int, seed: int = 0
                   ) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    n = len(x)
    while True:
        perm = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            s = perm[i:i + batch]
            yield {"x": x[s], "y": y[s]}
