"""Batching pipeline: shapes client shards into (num_batches, B, ...) arrays
consumable by scan-based local training, plus ``ClientBatch`` stacking for
the vectorized (vmap) execution backend and an infinite global-batch
iterator for the launcher's (non-federated) training path."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Sequence, Tuple

import numpy as np


def batched(x: np.ndarray, y: np.ndarray, batch: int, seed: int = 0
            ) -> Tuple[np.ndarray, np.ndarray]:
    """Shuffle and reshape to (nb, batch, ...); drops the ragged tail."""
    rng = np.random.default_rng(seed)
    n = (len(x) // batch) * batch
    if n == 0:
        raise ValueError(f"shard of {len(x)} < batch {batch}")
    perm = rng.permutation(len(x))[:n]
    xb = x[perm].reshape((n // batch, batch) + x.shape[1:])
    yb = y[perm].reshape((n // batch, batch) + y.shape[1:])
    return xb, yb


class ClientDataset:
    """One client's local train/test shards, pre-batched for lax.scan."""

    def __init__(self, cid: int, x: np.ndarray, y: np.ndarray,
                 batch: int, test_batch: int, test_frac: float = 0.2,
                 seed: int = 0):
        rng = np.random.default_rng(seed + cid)
        perm = rng.permutation(len(x))
        n_test = max(test_batch, int(len(x) * test_frac))
        n_test = (n_test // test_batch) * test_batch or test_batch
        te, tr = perm[:n_test], perm[n_test:]
        self.cid = cid
        self.train = batched(x[tr], y[tr], batch, seed=seed + cid)
        self.test = batched(x[te], y[te], test_batch, seed=seed + cid + 7)
        self.n_train = len(tr)

    @property
    def weight(self) -> float:
        return float(self.n_train)


@dataclasses.dataclass
class ClientBatch:
    """A group of client shards stacked along a leading axis so one
    jitted/vmapped dispatch can run every (individual, client) local update
    or (key, client) evaluation at once.

    ``xb``/``yb`` have shape (P, num_batches, B, ...) where P is the number
    of stacked shards.  Stacking requires uniform shard shapes; callers
    bucket ragged client sets with ``shape_buckets`` first.
    """
    xb: np.ndarray
    yb: np.ndarray
    weights: np.ndarray      # (P,) float32 — n_k for training-weighted avg
    client_ids: np.ndarray   # (P,) int

    @property
    def num_shards(self) -> int:
        return self.xb.shape[0]

    @property
    def samples_per_shard(self) -> int:
        return self.xb.shape[1] * self.xb.shape[2]

    @classmethod
    def stack(cls, clients: Sequence["ClientDataset"],
              split: str = "train") -> "ClientBatch":
        if not clients:
            raise ValueError("cannot stack an empty client group")
        if split not in ("train", "test"):
            raise ValueError(f"split must be 'train' or 'test', got {split!r}")
        shards = [(c.train if split == "train" else c.test) for c in clients]
        shapes = {s[0].shape for s in shards}
        if len(shapes) > 1:
            raise ValueError(
                f"ragged {split} shards {sorted(shapes)}; bucket clients by "
                "shape (shape_buckets) before stacking")
        return cls(
            xb=np.stack([np.asarray(s[0]) for s in shards]),
            yb=np.stack([np.asarray(s[1]) for s in shards]),
            weights=np.asarray([c.weight for c in clients], np.float32),
            client_ids=np.asarray([c.cid for c in clients], np.int64))


def shape_buckets(shapes: Sequence[tuple]) -> List[List[int]]:
    """Group indices by identical shape, preserving first-seen order (and
    the original order within a bucket) so vectorized execution stays
    deterministic."""
    order: Dict[tuple, List[int]] = {}
    for i, s in enumerate(shapes):
        order.setdefault(tuple(s), []).append(i)
    return list(order.values())


def make_clients(x: np.ndarray, y: np.ndarray, shards: List[np.ndarray],
                 batch: int, test_batch: int, seed: int = 0
                 ) -> List[ClientDataset]:
    return [ClientDataset(i, x[s], y[s], batch, test_batch, seed=seed)
            for i, s in enumerate(shards)]


def global_batches(x: np.ndarray, y: np.ndarray, batch: int, seed: int = 0
                   ) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    n = len(x)
    while True:
        perm = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            s = perm[i:i + batch]
            yield {"x": x[s], "y": y[s]}
