"""Transformer / SSM / hybrid / MoE / enc-dec stacks with scan-over-layers.

All families share one parameter layout convention: per-layer params are
stacked on a leading ``L`` axis (plus a branch axis ``(L, 3, ...)`` when the
paper's supernet is enabled) and the stack is traversed with ``lax.scan`` so
compile time and HLO size are depth-independent — a requirement for the
95-layer deepseek dry-run on 512 devices.

The supernet follows the paper's choice-block semantics adapted to
transformers (DESIGN.md Section 3): per layer, 4 branches
  0: identity (layer skip)          1: full block
  2: bottleneck (d_ff masked to /2) 3: lite (half the query heads masked)
selected by a traced int32 choice key => the server never recompiles as the
population moves through the search space.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    dense, dense_init, embed, embedding_init, mlp, mlp_init, rmsnorm,
    rmsnorm_init, sinusoidal_positions, unembed,
)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Branch masks for the supernet (static per config)
# ---------------------------------------------------------------------------

def branch_masks(cfg: ModelConfig) -> Dict[str, jax.Array]:
    m: Dict[str, jax.Array] = {}
    if cfg.d_ff:
        ff = jnp.arange(cfg.d_ff) < cfg.d_ff // 2
        m["ff"] = ff
    if cfg.num_heads:
        m["head"] = jnp.arange(cfg.num_heads) < cfg.num_heads // 2
    if cfg.num_experts:
        f = cfg.moe_d_ff or cfg.d_ff
        m["moe_ff"] = jnp.arange(f) < f // 2
    if cfg.ssm_state:
        m["state"] = jnp.arange(cfg.ssm_state) < cfg.ssm_state // 2
        m["ssm_head"] = jnp.arange(cfg.ssm_heads) < cfg.ssm_heads // 2
    return m


# ---------------------------------------------------------------------------
# Per-layer parameter init
# ---------------------------------------------------------------------------

def _attn_init(key, cfg, cross=False):
    return attn.attention_init(key, cfg.d_model, cfg.num_heads,
                               cfg.num_kv_heads, cfg.hd, cfg.jdtype,
                               qkv_bias=cfg.qkv_bias and not cross)


def block_init(key, cfg: ModelConfig, kind: str) -> Params:
    ks = jax.random.split(key, 6)
    d, dt = cfg.d_model, cfg.jdtype
    if kind == "dense":
        return {"ln1": rmsnorm_init(d, dt), "attn": _attn_init(ks[0], cfg),
                "ln2": rmsnorm_init(d, dt),
                "mlp": mlp_init(ks[1], d, cfg.d_ff, dt)}
    if kind == "moe":
        from repro.models.moe import moe_init
        return {"ln1": rmsnorm_init(d, dt), "attn": _attn_init(ks[0], cfg),
                "ln2": rmsnorm_init(d, dt), "moe": moe_init(ks[1], cfg)}
    if kind == "ssm":
        return {"ln": rmsnorm_init(d, dt), "ssm": ssm_mod.ssm_init(ks[0], cfg)}
    if kind == "enc":
        return {"ln1": rmsnorm_init(d, dt), "attn": _attn_init(ks[0], cfg),
                "ln2": rmsnorm_init(d, dt),
                "mlp": mlp_init(ks[1], d, cfg.d_ff, dt, gated=False)}
    if kind == "encdec":
        return {"ln1": rmsnorm_init(d, dt), "attn": _attn_init(ks[0], cfg),
                "lnx": rmsnorm_init(d, dt),
                "xattn": _attn_init(ks[1], cfg, cross=True),
                "ln2": rmsnorm_init(d, dt),
                "mlp": mlp_init(ks[2], d, cfg.d_ff, dt, gated=False)}
    raise ValueError(kind)


def _layer_kind(cfg: ModelConfig) -> str:
    return {"dense": "dense", "vlm": "dense", "moe": "moe", "ssm": "ssm",
            "hybrid": "ssm", "audio": "encdec"}[cfg.family]


def init_params(rng, cfg: ModelConfig) -> Params:
    kind = _layer_kind(cfg)
    k_emb, k_layers, k_extra, k_enc = jax.random.split(rng, 4)
    n_branch = 3 if cfg.supernet else None

    def one_layer(k):
        return block_init(k, cfg, kind)

    keys = jax.random.split(k_layers, cfg.num_layers * (n_branch or 1))
    if n_branch:
        keys = keys.reshape(cfg.num_layers, n_branch, 2)
        layers = jax.vmap(jax.vmap(one_layer))(keys)
    else:
        layers = jax.vmap(one_layer)(keys)

    params: Params = {
        "embed": embedding_init(k_emb, cfg.vocab_size, cfg.d_model, cfg.jdtype),
        "final_ln": rmsnorm_init(cfg.d_model, cfg.jdtype),
        "layers": layers,
    }
    if cfg.family == "hybrid":
        params["shared"] = block_init(k_extra, cfg, "dense")
    if cfg.family == "vlm":
        params["proj"] = dense_init(k_extra, cfg.d_model, cfg.d_model,
                                    cfg.jdtype, with_bias=True)
    if cfg.family == "audio":
        enc_keys = jax.random.split(k_enc, cfg.encoder_layers)
        params["encoder"] = jax.vmap(lambda k: block_init(k, cfg, "enc"))(enc_keys)
        params["enc_ln"] = rmsnorm_init(cfg.d_model, cfg.jdtype)
    return params


# ---------------------------------------------------------------------------
# Forward blocks (full sequence: train / prefill)
# ---------------------------------------------------------------------------

def _attn_kw(cfg, window):
    return dict(num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.hd, rope_style=cfg.rope_style,
                theta=cfg.rope_theta, window=window)


def _dense_block_fwd(p, h, positions, cfg, window, backend,
                     ff_mask=None, head_mask=None, causal=True):
    h = h + attn.self_attention(p["attn"], rmsnorm(p["ln1"], h), positions,
                                causal=causal, head_mask=head_mask,
                                backend=backend, **_attn_kw(cfg, window))
    h = h + mlp(p["mlp"], rmsnorm(p["ln2"], h), ff_mask=ff_mask)
    return h, jnp.float32(0.0)


def _moe_block_fwd(p, h, positions, cfg, window, backend,
                   ff_mask=None, head_mask=None):
    from repro.models.moe import moe_apply
    h = h + attn.self_attention(p["attn"], rmsnorm(p["ln1"], h), positions,
                                head_mask=head_mask, backend=backend,
                                **_attn_kw(cfg, window))
    y, aux = moe_apply(p["moe"], rmsnorm(p["ln2"], h), cfg, ff_mask=ff_mask)
    return h + y, aux


def _ssm_block_fwd(p, h, cfg, backend, state_mask=None, head_mask=None):
    y = ssm_mod.ssm_forward(p["ssm"], rmsnorm(p["ln"], h), cfg,
                            state_mask=state_mask, head_mask=head_mask,
                            backend=backend)
    return h + y, jnp.float32(0.0)


def _make_branch_fns(cfg, masks, positions, window, backend):
    """4 choice-block branches with identical (p, h) -> (h, aux) signatures."""
    kind = _layer_kind(cfg)

    def identity(p, h):
        return h, jnp.float32(0.0)

    if kind == "dense":
        full = lambda p, h: _dense_block_fwd(p, h, positions, cfg, window, backend)
        bottle = lambda p, h: _dense_block_fwd(p, h, positions, cfg, window,
                                               backend, ff_mask=masks["ff"])
        lite = lambda p, h: _dense_block_fwd(p, h, positions, cfg, window,
                                             backend, head_mask=masks["head"])
    elif kind == "moe":
        full = lambda p, h: _moe_block_fwd(p, h, positions, cfg, window, backend)
        bottle = lambda p, h: _moe_block_fwd(p, h, positions, cfg, window,
                                             backend, ff_mask=masks["moe_ff"])
        lite = lambda p, h: _moe_block_fwd(p, h, positions, cfg, window,
                                           backend, head_mask=masks["head"])
    elif kind == "ssm":
        full = lambda p, h: _ssm_block_fwd(p, h, cfg, backend)
        bottle = lambda p, h: _ssm_block_fwd(p, h, cfg, backend,
                                             state_mask=masks["state"])
        lite = lambda p, h: _ssm_block_fwd(p, h, cfg, backend,
                                           head_mask=masks["ssm_head"])
    else:
        raise ValueError(f"supernet unsupported for kind {kind}")
    return identity, full, bottle, lite


def _constrain_activations(h):
    """Pin the residual stream to (data-sharded batch, replicated seq/d).

    The embedding gather reads a (vocab x d) table sharded (model, data);
    without this constraint GSPMD propagates the table's sharding into the
    residual stream entering the layer scan, replicating every layer's
    activations over part of the mesh (measured ~17 GB/layer/device for
    deepseek at train_4k)."""
    from repro.launch import policy
    mesh = policy.get_mesh()
    if mesh is None:
        return h
    from jax.sharding import NamedSharding, PartitionSpec as P
    dax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if h.shape[0] % policy.data_axis_size(mesh) != 0:
        return h
    spec = P(dax, *([None] * (h.ndim - 1)))
    return jax.lax.with_sharding_constraint(h, NamedSharding(mesh, spec))


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array, *,
            prefix: Optional[jax.Array] = None,
            choice_key: Optional[jax.Array] = None,
            window: int = 0, backend: str = "xla", remat: bool = False,
            return_cache: bool = False, cache_len: int = 0,
            return_hidden: bool = False, unroll: bool = False
            ) -> Tuple[jax.Array, jax.Array, Optional[Params]]:
    """Full-sequence forward for every decoder-bearing family.

    tokens: (B, S) int32.  prefix: stub frontend embeddings — (B, P, d) patch
    embeddings (vlm) or (B, F, d) audio frames (audio; routed through the
    encoder).  Returns (logits over the token positions, moe aux loss,
    optional prefill cache).
    """
    kind = _layer_kind(cfg)
    b, s = tokens.shape
    h = _constrain_activations(embed(params["embed"], tokens))
    n_prefix = 0
    enc_out = None

    if cfg.family == "vlm":
        assert prefix is not None
        pfx = dense(params["proj"], prefix.astype(h.dtype))
        h = jnp.concatenate([pfx, h], axis=1)
        n_prefix = pfx.shape[1]
    if cfg.family == "audio":
        assert prefix is not None
        enc_out = encode(params, cfg, prefix, backend=backend,
                         unroll=unroll)
        h = h + sinusoidal_positions(s, cfg.d_model, h.dtype)[None]

    total = h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(total, dtype=jnp.int32), (b, total))
    masks = branch_masks(cfg) if cfg.supernet else {}

    # ---- scan body -------------------------------------------------------
    def body(carry, xs):
        h, aux = carry
        if cfg.supernet:
            p_b, key_l, li = xs   # branch params pre-gathered outside scan
            fns = _make_branch_fns(cfg, masks, positions, window, backend)
            h, a = jax.lax.switch(key_l, fns, p_b, h)
        else:
            p_l, li = xs
            if kind == "dense":
                h, a = _dense_block_fwd(p_l, h, positions, cfg, window, backend)
            elif kind == "moe":
                h, a = _moe_block_fwd(p_l, h, positions, cfg, window, backend)
            elif kind == "ssm":
                h, a = _ssm_block_fwd(p_l, h, cfg, backend)
            elif kind == "encdec":
                h = h + attn.self_attention(
                    p_l["attn"], rmsnorm(p_l["ln1"], h), positions,
                    backend=backend, **_attn_kw(cfg, window))
                kv = attn.encode_kv(p_l["xattn"], enc_out,
                                    num_kv_heads=cfg.num_kv_heads)
                h = h + attn.cross_attention(
                    p_l["xattn"], rmsnorm(p_l["lnx"], h), kv,
                    num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                    head_dim=cfg.hd)
                h = h + mlp(p_l["mlp"], rmsnorm(p_l["ln2"], h))
                a = jnp.float32(0.0)
            else:
                raise ValueError(kind)
        if cfg.family == "hybrid":
            h = jax.lax.cond(
                jnp.mod(li, cfg.attn_every) == cfg.attn_every - 1,
                lambda hh: _dense_block_fwd(params["shared"], hh, positions,
                                            cfg, window, backend)[0],
                lambda hh: hh, h)
        return (h, aux + a), None

    if remat:
        body = jax.checkpoint(body)

    lidx = jnp.arange(cfg.num_layers, dtype=jnp.int32)
    if cfg.supernet:
        # gather each layer's SELECTED branch once, outside the scan —
        # otherwise the scan streams all 3 branches' weights from HBM
        # every step (identity clamps to branch 0; its params are unused)
        ck = jnp.maximum(choice_key - 1, 0)
        sel = jax.tree.map(
            lambda x: jax.vmap(lambda xl, i: xl[i])(x, ck),
            params["layers"])
        xs = (sel, choice_key, lidx)
    else:
        xs = (params["layers"], lidx)
    (h, aux), _ = jax.lax.scan(body, (h, jnp.float32(0.0)), xs,
                               unroll=cfg.num_layers if unroll else 1)

    h = rmsnorm(params["final_ln"], h)
    if return_hidden:
        # caller fuses unembed + loss (fused_cross_entropy) — do not
        # materialize the (B, S, V) logits here
        logits = h[:, n_prefix:, :]
    else:
        logits = unembed(params["embed"], h[:, n_prefix:, :])

    cache = None
    if return_cache:
        cache = prefill_cache(params, cfg, tokens, prefix=prefix,
                              window=window, cache_len=cache_len or total,
                              enc_out=enc_out)
    return logits, aux, cache


def encode(params: Params, cfg: ModelConfig, frames: jax.Array, *,
           backend: str = "xla", unroll: bool = False) -> jax.Array:
    """Whisper-style bidirectional encoder over stub frame embeddings."""
    h = frames.astype(cfg.jdtype)
    b, f, _ = h.shape
    h = h + sinusoidal_positions(f, cfg.d_model, h.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32), (b, f))

    def body(h, p_l):
        h, _ = _dense_block_fwd(p_l, h, positions, cfg, 0, backend,
                                causal=False)
        return h, None

    h, _ = jax.lax.scan(body, h, params["encoder"],
                        unroll=cfg.encoder_layers if unroll else 1)
    return rmsnorm(params["enc_ln"], h)


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / single-token decode
# ---------------------------------------------------------------------------

def init_cache(params: Params, cfg: ModelConfig, batch: int, cache_len: int,
               enc_len: int = 0) -> Params:
    kind = _layer_kind(cfg)
    L = cfg.num_layers
    dt = cfg.jdtype

    def rep(tree):
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (L,) + x.shape), tree)

    cache: Params = {"t": jnp.zeros((), jnp.int32)}
    if kind in ("dense", "moe"):
        cache["layers"] = rep(attn.init_cache(batch, cfg.num_kv_heads, cfg.hd,
                                              cache_len, dt))
    elif kind == "ssm":
        cache["layers"] = rep(ssm_mod.init_ssm_cache(batch, cfg, dt))
    elif kind == "encdec":
        c = attn.init_cache(batch, cfg.num_kv_heads, cfg.hd, cache_len, dt)
        c["cross_k"] = jnp.zeros((batch, enc_len, cfg.num_kv_heads, cfg.hd), dt)
        c["cross_v"] = jnp.zeros((batch, enc_len, cfg.num_kv_heads, cfg.hd), dt)
        cache["layers"] = rep(c)
    if cfg.family == "hybrid":
        # one KV cache per shared-block application point
        n_app = cfg.num_layers // cfg.attn_every
        c = attn.init_cache(batch, cfg.num_kv_heads, cfg.hd, cache_len, dt)
        cache["shared"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_app,) + x.shape), c)
    return cache


def prefill_cache(params, cfg, tokens, *, prefix=None, window=0,
                  cache_len=0, enc_out=None):
    """Build a decode cache by replaying the sequence (reference path).

    Production prefill fuses this with ``forward``; for the dry-run shapes we
    lower ``forward(return_cache=False)`` (prefill compute) and
    ``decode_step`` (steady-state decode) separately, so this replay path is
    only used by tests and the CPU serving example.
    """
    b, s = tokens.shape
    cache = init_cache(params, cfg, b, cache_len or s,
                       enc_len=0 if enc_out is None else enc_out.shape[1])
    if enc_out is not None:
        def fill_cross(c_l, p_l):
            k, v = attn.encode_kv(p_l["xattn"], enc_out,
                                  num_kv_heads=cfg.num_kv_heads)
            c_l = dict(c_l)
            c_l["cross_k"], c_l["cross_v"] = k, v
            return c_l
        cache["layers"] = jax.vmap(fill_cross)(cache["layers"], params["layers"])

    def step(cache, tok):
        logits, cache = decode_step(params, cfg, tok[:, None], cache,
                                    window=window)
        return cache, logits[:, 0]

    cache, _ = jax.lax.scan(step, cache, jnp.moveaxis(tokens, 1, 0))
    return cache


def decode_step(params: Params, cfg: ModelConfig, token: jax.Array,
                cache: Params, *, window: int = 0, unroll: bool = False
                ) -> Tuple[jax.Array, Params]:
    """One decode step.  token: (B, 1) int32 -> (logits (B, 1, V), cache)."""
    kind = _layer_kind(cfg)
    t = cache["t"]
    h = _constrain_activations(embed(params["embed"], token))
    if cfg.family == "audio":
        h = h + sinusoidal_positions(1, cfg.d_model, h.dtype, offset=t)[None]
    kw = dict(num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
              head_dim=cfg.hd, rope_style=cfg.rope_style, theta=cfg.rope_theta,
              window=window)

    def body(carry, xs):
        h, sh_cache = carry
        p_l, c_l, li = xs
        if kind in ("dense", "moe"):
            y, c_l2 = attn.decode_self_attention(p_l["attn"],
                                                 rmsnorm(p_l["ln1"], h),
                                                 c_l, t, **kw)
            h = h + y
            if kind == "moe":
                from repro.models.moe import moe_apply
                y, _ = moe_apply(p_l["moe"], rmsnorm(p_l["ln2"], h), cfg)
                h = h + y
            else:
                h = h + mlp(p_l["mlp"], rmsnorm(p_l["ln2"], h))
        elif kind == "ssm":
            y, c_l2 = ssm_mod.ssm_decode_step(p_l["ssm"],
                                              rmsnorm(p_l["ln"], h), c_l, cfg)
            h = h + y
        elif kind == "encdec":
            c_self = {"k": c_l["k"], "v": c_l["v"], "pos": c_l["pos"]}
            y, c_self = attn.decode_self_attention(
                p_l["attn"], rmsnorm(p_l["ln1"], h), c_self, t, **kw)
            h = h + y
            h = h + attn.cross_attention(
                p_l["xattn"], rmsnorm(p_l["lnx"], h),
                (c_l["cross_k"], c_l["cross_v"]),
                num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.hd)
            h = h + mlp(p_l["mlp"], rmsnorm(p_l["ln2"], h))
            c_l2 = dict(c_l)
            c_l2.update(c_self)
        else:
            raise ValueError(kind)

        if cfg.family == "hybrid":
            # the shared attention+mlp block fires every attn_every layers,
            # each application point owning its own KV cache slice.
            def apply_shared(args):
                hh, shc = args
                idx = li // cfg.attn_every
                c = jax.tree.map(lambda x: x[idx], shc)
                y, c2 = attn.decode_self_attention(
                    params["shared"]["attn"],
                    rmsnorm(params["shared"]["ln1"], hh), c, t, **kw)
                hh = hh + y
                hh = hh + mlp(params["shared"]["mlp"],
                              rmsnorm(params["shared"]["ln2"], hh))
                shc = jax.tree.map(
                    lambda x, u: jax.lax.dynamic_update_index_in_dim(
                        x, u, idx, 0), shc, c2)
                return hh, shc

            h, sh_cache = jax.lax.cond(
                jnp.mod(li, cfg.attn_every) == cfg.attn_every - 1,
                apply_shared, lambda a: a, (h, sh_cache))
        return (h, sh_cache), c_l2

    lidx = jnp.arange(cfg.num_layers, dtype=jnp.int32)
    sh0 = cache.get("shared")
    (h, sh_cache), new_layers = jax.lax.scan(
        body, (h, sh0), (params["layers"], cache["layers"], lidx),
        unroll=cfg.num_layers if unroll else 1)
    new_cache = dict(cache)
    new_cache["layers"] = new_layers
    if cfg.family == "hybrid":
        new_cache["shared"] = sh_cache

    h = rmsnorm(params["final_ln"], h)
    logits = unembed(params["embed"], h)
    new_cache["t"] = t + 1
    return logits, new_cache
