"""The paper's master model: CIFAR CNN supernet (Fig. 3 / Fig. 4), faithful.

Conv stem -> 12 choice blocks -> global-avg-pool -> FC.  Each choice block
has 4 branches: identity / residual / inverted-residual (MobileNetV2) /
depthwise-separable, in 'normal' (C->C) or 'reduction' (C->2C, spatial /2)
form depending on position.  Only normal blocks carry shortcut connections
(paper Fig. 4).  BatchNorm affine parameters and moving statistics are
DISABLED per Section IV.C — normalization uses current-batch statistics only.

Branch selection is a traced int32 per block (``lax.switch``), so one
compilation serves every choice key — unlike the paper's per-key PyTorch
module rebuild.
"""
from __future__ import annotations

import math
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs import cifar_supernet as cs
from repro.configs.base import ModelConfig

BRANCH_NAMES = ("identity", "residual", "inverted", "sepconv")


def _conv_init(key, kh, kw, cin, cout, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(kh * kw * cin)
    return jax.random.uniform(key, (kh, kw, cin, cout), dtype,
                              minval=-scale, maxval=scale)


def conv(x, w, stride=1, groups=1):
    if groups > 1:
        return _depthwise(x, w, stride)
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=1)


def _depthwise(x, w, stride=1):
    """Depthwise KxK conv as K^2 shifted elementwise multiply-adds.

    XLA:CPU lowers grouped convolutions (and especially their transpose in
    the backward pass) to a per-group loop that is ~100x slower than this
    formulation; on TPU both lower to the same fused elementwise HLO.
    w: (K, K, 1, C) (HWIO depthwise layout).
    """
    k = w.shape[0]
    ph = pw = k // 2
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    h, wdt = x.shape[1], x.shape[2]
    out = None
    for i in range(k):
        for j in range(k):
            piece = xp[:, i: i + h, j: j + wdt, :] * w[i, j, 0]
            out = piece if out is None else out + piece
    if stride > 1:
        out = out[:, ::stride, ::stride, :]
    return out


def bn(x, eps=1e-5):
    """Paper-faithful BN: batch statistics only, no affine, no moving stats."""
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps)


def _dw_init(key, c, dtype=jnp.float32):
    # depthwise 3x3: HWIO with I=1, groups=c
    scale = 1.0 / math.sqrt(9)
    return jax.random.uniform(key, (3, 3, 1, c), dtype,
                              minval=-scale, maxval=scale)


# ---------------------------------------------------------------------------
# Branch param init (heterogeneous shapes => per-block dicts, no stacking)
# ---------------------------------------------------------------------------

def branch_init(key, name: str, cin: int, cout: int) -> Dict:
    red = cout != cin
    ks = jax.random.split(key, 6)
    if name == "identity":
        if not red:
            return {"_": jnp.zeros((1,), jnp.float32)}  # placeholder leaf
        half = cout // 2
        return {"pw1": _conv_init(ks[0], 1, 1, cin, half),
                "pw2": _conv_init(ks[1], 1, 1, cin, half)}
    if name == "residual":
        return {"c1": _conv_init(ks[0], 3, 3, cin, cout),
                "c2": _conv_init(ks[1], 3, 3, cout, cout)}
    if name == "inverted":
        hid = 4 * cin
        return {"pw1": _conv_init(ks[0], 1, 1, cin, hid),
                "dw": _dw_init(ks[1], hid),
                "pw2": _conv_init(ks[2], 1, 1, hid, cout)}
    if name == "sepconv":
        return {"dw1": _dw_init(ks[0], cin),
                "pw1": _conv_init(ks[1], 1, 1, cin, cout),
                "dw2": _dw_init(ks[2], cout),
                "pw2": _conv_init(ks[3], 1, 1, cout, cout)}
    raise ValueError(name)


def branch_apply(name: str, p: Dict, x, cin: int, cout: int):
    red = cout != cin
    stride = 2 if red else 1
    if name == "identity":
        if not red:
            return x
        a = conv(x, p["pw1"], stride=2)
        b = conv(x, p["pw2"], stride=2)
        return jnp.concatenate([a, b], axis=-1)
    if name == "residual":
        h = jax.nn.relu(bn(conv(x, p["c1"], stride=stride)))
        h = bn(conv(h, p["c2"]))
        if not red:
            h = h + x
        return jax.nn.relu(h)
    if name == "inverted":
        h = jax.nn.relu(bn(conv(x, p["pw1"])))
        h = jax.nn.relu(bn(conv(h, p["dw"], stride=stride,
                                groups=h.shape[-1])))
        h = bn(conv(h, p["pw2"]))
        if not red:
            h = h + x
        return h
    if name == "sepconv":
        h = conv(x, p["dw1"], stride=stride, groups=cin)
        h = jax.nn.relu(bn(conv(h, p["pw1"])))
        h = conv(h, p["dw2"], groups=cout)
        h = jax.nn.relu(bn(conv(h, p["pw2"])))
        if not red:
            h = h + x
        return h
    raise ValueError(name)


# ---------------------------------------------------------------------------
# Supernet init / forward
# ---------------------------------------------------------------------------

def init_params(rng, cfg: ModelConfig) -> Dict:
    n = cfg.num_layers
    chans = cs.channels_for(n)
    stem_c = cs.stem_channels_for(n)
    keys = jax.random.split(rng, n * 4 + 2)
    params: Dict = {
        "stem": _conv_init(keys[-2], 3, 3, 3, stem_c),
        "fc": {"w": _conv_init(keys[-1], 1, 1, chans[-1],
                               cs.NUM_CLASSES)[0, 0],
               "b": jnp.zeros((cs.NUM_CLASSES,), jnp.float32)},
        "blocks": [],
    }
    cin = stem_c
    for i in range(n):
        cout = chans[i]
        blk = {nm: branch_init(keys[i * 4 + j], nm, cin, cout)
               for j, nm in enumerate(BRANCH_NAMES)}
        params["blocks"].append(blk)
        cin = cout
    return params


def forward(params: Dict, images, choice_key) -> jax.Array:
    """images: (B, H, W, 3) float32; choice_key: (num_blocks,) int32."""
    n = len(params["blocks"])
    chans = cs.channels_for(n)
    cin = cs.stem_channels_for(n)
    h = jax.nn.relu(bn(conv(images, params["stem"])))
    for i, blk in enumerate(params["blocks"]):
        cout = chans[i]
        fns = [
            (lambda p=blk[nm], nm=nm, ci=cin, co=cout:
             (lambda hh: branch_apply(nm, p, hh, ci, co)))()
            for nm in BRANCH_NAMES
        ]
        h = jax.lax.switch(choice_key[i], fns, h)
        cin = cout
    h = jnp.mean(h, axis=(1, 2))
    return h @ params["fc"]["w"] + params["fc"]["b"]
