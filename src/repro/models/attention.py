"""Grouped-query attention for every transformer family in the zoo.

Supports: GQA (num_kv_heads <= num_heads), RoPE 1d / 2d(chatglm half-dim) /
none, optional QKV bias, causal or sliding-window masks, cross-attention
(whisper), single-token decode against a (ring-buffered) KV cache, and a
per-head mask used by the supernet 'lite' branch.

The softmax(QK^T)V core can be routed to the Pallas flash-attention kernel
(``backend='pallas'``) or to the pure-XLA einsum path (default; also the
reference oracle for the kernel).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense, dense_init

NEG_INF = -1e30


def attention_init(key, d_model, num_heads, num_kv_heads, head_dim, dtype,
                   qkv_bias=False, cross=False):
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, d_model, num_heads * head_dim, dtype, qkv_bias),
        "wk": dense_init(kk, d_model, num_kv_heads * head_dim, dtype, qkv_bias),
        "wv": dense_init(kv, d_model, num_kv_heads * head_dim, dtype, qkv_bias),
        "wo": dense_init(ko, num_heads * head_dim, d_model, dtype),
    }
    return p


def _split_heads(x, n):
    b, s, _ = x.shape
    return x.reshape(b, s, n, -1)


def _attend(q, k, v, mask, head_mask=None):
    """q:(B,S,H,D) k,v:(B,T,Kh,D) mask:(B|1,S,T) bool -> (B,S,H*D).

    GQA is handled by repeating K/V to the full head count rather than
    reshaping Q to (Kh, G, D): splitting the head axis breaks tensor-
    parallel sharding whenever Kh or G alone does not divide the model
    axis (deepseek: Kh=8, G=8 on a 16-way axis replicated every score
    tensor — ~5 GB/layer/device at train_4k).  The repeat is a broadcast
    that stays sharded over the full H.
    """
    b, s, h, d = q.shape
    kh = k.shape[2]
    if kh != h:
        k = jnp.repeat(k, h // kh, axis=2)
        v = jnp.repeat(v, h // kh, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(d).astype(jnp.float32)
    scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, v.astype(jnp.float32))
    if head_mask is not None:
        out = out * head_mask.astype(out.dtype)[None, None, :, None]
    return out.reshape(b, s, h * d).astype(v.dtype)


def _attend_chunked(q, k, v, *, causal=True, window=0, chunk=512,
                    head_mask=None):
    """Flash-style attention in pure XLA: scan over query blocks so only a
    (chunk x T) score tile is live at once (vs the full (S x T) tensor of
    ``_attend``); each tile is rematerialized in the backward pass.

    At prefill_32k scale the full fp32 scores are ~8.6 GB/device/layer —
    this caps them at chunk/S of that.  K/V must already be repeated to
    full heads.  q: (B, S, H, D); k, v: (B, T, Kh, D).
    """
    b, s, h, d = q.shape
    kh = k.shape[2]
    if kh != h:
        k = jnp.repeat(k, h // kh, axis=2)
        v = jnp.repeat(v, h // kh, axis=2)
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = (s + pad) // chunk
    qs = q.reshape(b, nq, chunk, h, d)
    t_len = k.shape[1]
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    k_pos = jnp.arange(t_len)

    @jax.checkpoint
    def block(qc, ci):
        q_pos = ci * chunk + jnp.arange(chunk) + (t_len - s - pad)
        m = jnp.ones((chunk, t_len), dtype=bool)
        if causal:
            m = m & (k_pos[None, :] <= q_pos[:, None])
        if window:
            m = m & (k_pos[None, :] > q_pos[:, None] - window)
        # K/V stay in model dtype (they are re-read once per q-chunk —
        # casting them fp32 up front doubles the streamed bytes); the MXU
        # accumulates in fp32 via preferred_element_type.
        sc = jnp.einsum("bchd,bthd->bhct", qc, k,
                        preferred_element_type=jnp.float32) * scale
        sc = jnp.where(m[None, None], sc, NEG_INF)
        p = jax.nn.softmax(sc, axis=-1)
        return jnp.einsum("bhct,bthd->bchd", p.astype(v.dtype), v,
                          preferred_element_type=jnp.float32)

    def body(_, xs):
        qc, ci = xs
        return None, block(qc, ci)

    _, out = jax.lax.scan(body, None,
                          (jnp.moveaxis(qs, 1, 0),
                           jnp.arange(nq, dtype=jnp.int32)))
    out = jnp.moveaxis(out, 0, 1).reshape(b, s + pad, h, d)[:, :s]
    if head_mask is not None:
        out = out * head_mask.astype(out.dtype)[None, None, :, None]
    return out.reshape(b, s, h * d).astype(v.dtype)


def causal_mask(s, t=None, window=0):
    t = t or s
    qi = jnp.arange(s)[:, None] + (t - s)
    ki = jnp.arange(t)[None, :]
    m = ki <= qi
    if window:
        m = m & (ki > qi - window)
    return m[None]


def _maybe_shard_kv_seq(k, v, num_heads):
    """When the head count does not divide the model axis (whisper: 20,
    internvl: 14 on a 16-way axis) GSPMD replicates the attention scores
    over the whole model axis — measured 6.4x temp-memory blowup at
    train_4k.  Constrain K/V to shard the kv-sequence dim over 'model'
    instead; XLA then computes partial softmax + all-reduce (flash-decode
    style)."""
    from repro.launch import policy
    mesh = policy.get_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return k, v
    m = mesh.shape["model"]
    if num_heads % m == 0 or k.shape[1] % m != 0:
        return k, v
    from jax.sharding import NamedSharding, PartitionSpec as P
    dax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = dax if k.shape[0] % policy.data_axis_size(mesh) == 0 else None
    sh = NamedSharding(mesh, P(bspec, "model", None, None))
    return (jax.lax.with_sharding_constraint(k, sh),
            jax.lax.with_sharding_constraint(v, sh))


def self_attention(p, x, positions, *, num_heads, num_kv_heads, head_dim,
                   rope_style="1d", theta=10000.0, causal=True, window=0,
                   head_mask=None, backend="xla"):
    """Full-sequence self attention (train / prefill)."""
    q = _split_heads(dense(p["wq"], x), num_heads)
    k = _split_heads(dense(p["wk"], x), num_kv_heads)
    v = _split_heads(dense(p["wv"], x), num_kv_heads)
    q = apply_rope(q, positions, theta, rope_style)
    k = apply_rope(k, positions, theta, rope_style)
    k, v = _maybe_shard_kv_seq(k, v, num_heads)
    s = x.shape[1]
    if backend == "pallas":
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=causal, window=window)
        b = x.shape[0]
        if head_mask is not None:
            out = out * head_mask.astype(out.dtype)[None, None, :, None]
        out = out.reshape(b, s, num_heads * head_dim)
    elif backend == "chunked":
        out = _attend_chunked(q, k, v, causal=causal, window=window,
                              head_mask=head_mask)
    else:
        mask = causal_mask(s, window=window) if causal else \
            jnp.ones((1, s, s), dtype=bool)
        out = _attend(q, k, v, mask, head_mask)
    return dense(p["wo"], out)


def cross_attention(p, x, enc_kv, *, num_heads, num_kv_heads, head_dim,
                    head_mask=None):
    """Decoder->encoder attention.  ``enc_kv`` = (k, v) precomputed from the
    encoder output, each (B, T_enc, Kh, D)."""
    q = _split_heads(dense(p["wq"], x), num_heads)
    k, v = enc_kv
    mask = jnp.ones((1, x.shape[1], k.shape[1]), dtype=bool)
    out = _attend(q, k, v, mask, head_mask)
    return dense(p["wo"], out)


def encode_kv(p, enc_out, *, num_kv_heads):
    """Precompute cross-attention K/V from encoder output (once per request)."""
    k = _split_heads(dense(p["wk"], enc_out), num_kv_heads)
    v = _split_heads(dense(p["wv"], enc_out), num_kv_heads)
    return k, v


def init_cache(batch, num_kv_heads, head_dim, cache_len, dtype):
    """KV cache for one layer.  ``pos`` holds the absolute position stored in
    each slot (-1 = empty) so the same code serves both a full cache and a
    sliding-window ring buffer."""
    return {
        "k": jnp.zeros((batch, cache_len, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, cache_len, num_kv_heads, head_dim), dtype),
        "pos": jnp.full((cache_len,), -1, jnp.int32),
    }


def _hd_sharding(x, batch):
    """NamedSharding pinning the last (head_dim) axis to 'model' — the
    decode cache's stored layout.  None when no mesh / not divisible."""
    from repro.launch import policy
    mesh = policy.get_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return None
    if x.shape[-1] % mesh.shape["model"] != 0:
        return None
    from jax.sharding import NamedSharding, PartitionSpec as P
    dax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = dax if batch % policy.data_axis_size(mesh) == 0 else None
    return NamedSharding(
        mesh, P(bspec, *([None] * (x.ndim - 2)), "model"))


def decode_self_attention(p, x, cache, t, *, num_heads, num_kv_heads,
                          head_dim, rope_style="1d", theta=10000.0, window=0,
                          head_mask=None):
    """One-token decode.  x: (B, 1, d); t: scalar int32 absolute position.
    Writes slot ``t % cache_len`` (a ring buffer when cache_len < seq_len).

    Q/K/V and the updated cache are pinned to the cache's stored layout
    (head_dim sharded over 'model'): the ring write is then shard-local and
    the score einsum contracts the sharded head_dim into a tiny psum —
    without the pin GSPMD re-shards the entire multi-GB cache around every
    update (EXPERIMENTS.md §Perf, hillclimb B).
    """
    q = _split_heads(dense(p["wq"], x), num_heads)
    k = _split_heads(dense(p["wk"], x), num_kv_heads)
    v = _split_heads(dense(p["wv"], x), num_kv_heads)
    pos = jnp.full((x.shape[0], 1), t, jnp.int32)
    q = apply_rope(q, pos, theta, rope_style)
    k = apply_rope(k, pos, theta, rope_style)
    sh = _hd_sharding(q, q.shape[0])
    if sh is not None:
        q = jax.lax.with_sharding_constraint(q, sh)
        k = jax.lax.with_sharding_constraint(k, sh)
        v = jax.lax.with_sharding_constraint(v, sh)
    cache_len = cache["k"].shape[1]
    slot = jnp.mod(t, cache_len)
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    if sh is not None:
        ck = jax.lax.with_sharding_constraint(ck, sh)
        cv = jax.lax.with_sharding_constraint(cv, sh)
    cpos = jax.lax.dynamic_update_slice(
        cache["pos"], jnp.reshape(t, (1,)).astype(jnp.int32), (slot,))
    valid = (cpos >= 0) & (cpos <= t)
    if window:
        valid = valid & (cpos > t - window)
    mask = valid[None, None, :]
    if sh is not None:
        out = _attend_decode_pinned(q, ck, cv, mask, head_mask, sh)
    else:
        out = _attend(q, ck, cv, mask, head_mask)
    out = dense(p["wo"], out)
    return out, {"k": ck, "v": cv, "pos": cpos}


def _attend_decode_pinned(q, k, v, mask, head_mask, hd_sh):
    """Decode attention that never re-shards the cache: the score einsum
    contracts the model-sharded head_dim (psum of a tiny (B,H,1,T) tensor),
    probs are pinned replicated-over-model, and the probs x V einsum reads
    V in its stored bf16 hd-sharded layout.  Without the pins GSPMD
    all-gathers the fp32-upcast V cache every layer (hillclimb B3)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    b, s, h, d = q.shape
    kh = k.shape[2]
    if kh != h:
        k = jnp.repeat(k, h // kh, axis=2)
        v = jnp.repeat(v, h // kh, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(d).astype(jnp.float32)
    rep = NamedSharding(hd_sh.mesh, P(hd_sh.spec[0], None, None, None))
    scores = jax.lax.with_sharding_constraint(scores, rep)
    scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jax.lax.with_sharding_constraint(probs, rep)
    out = jnp.einsum("bhst,bthd->bshd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = jax.lax.with_sharding_constraint(out, hd_sh)
    if head_mask is not None:
        out = out * head_mask.astype(out.dtype)[None, None, :, None]
    return out.reshape(b, s, h * d).astype(v.dtype)
