from repro.models import attention, cnn, layers, moe, ssm, transformer

__all__ = ["attention", "cnn", "layers", "moe", "ssm", "transformer"]
