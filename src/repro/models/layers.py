"""Primitive layers shared by every model family (pure functional JAX).

Parameters are plain pytrees (nested dicts of jnp arrays); every layer is a
pair of ``init_*`` / ``apply`` functions.  Models stack per-layer params on a
leading axis and scan over them, so compile time is depth-independent.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def uniform_init(key, shape, scale, dtype):
    return jax.random.uniform(key, shape, dtype=jnp.float32, minval=-scale,
                              maxval=scale).astype(dtype)


def dense_init(key, d_in, d_out, dtype, with_bias=False):
    scale = 1.0 / math.sqrt(d_in)
    p = {"w": uniform_init(key, (d_in, d_out), scale, dtype)}
    if with_bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = jnp.einsum("...i,io->...o", x, p["w"])
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------------
# Norms.  RMSNorm everywhere (no running statistics): this is the TPU-native
# application of the paper's observation that BN statistics diverge under
# weight sharing + federated averaging (DESIGN.md Section 3).
# ---------------------------------------------------------------------------

def rmsnorm_init(d, dtype):
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * p["g"].astype(jnp.float32)).astype(dt)


def layernorm_init(d, dtype):
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["g"].astype(jnp.float32) + p["b"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Embeddings / unembedding
# ---------------------------------------------------------------------------

def embedding_init(key, vocab, d, dtype):
    return {"table": uniform_init(key, (vocab, d), 0.02, dtype)}


def embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p, x):
    # tied-weights unembedding: logits over vocab
    return jnp.einsum("...d,vd->...v", x, p["table"])


def sinusoidal_positions(seq_len, d, dtype=jnp.float32, offset=0):
    """Whisper-style sinusoidal position embeddings.  ``offset`` may be a
    traced scalar (decode step at position t)."""
    pos = (jnp.arange(seq_len, dtype=jnp.float32)
           + jnp.asarray(offset, jnp.float32))[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-math.log(10000.0) * dim / d)
    ang = pos * inv
    out = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (1D llama-style and 2D/half-dim chatglm-style)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim, theta, dtype=jnp.float32):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta=10000.0, style="1d"):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    if style == "none":
        return x
    hd = x.shape[-1]
    rot = hd if style == "1d" else hd // 2   # chatglm rotates only half
    freqs = rope_freqs(rot, theta)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, rot/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    rotated = jnp.stack([o1, o2], axis=-1).reshape(xr.shape).astype(x.dtype)
    if rot == hd:
        return rotated
    return jnp.concatenate([rotated, x[..., rot:]], axis=-1)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU) and plain GELU MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d_model, d_ff, dtype, gated=True):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"wi": dense_init(k1, d_model, d_ff, dtype),
         "wo": dense_init(k2, d_ff, d_model, dtype)}
    if gated:
        p["wg"] = dense_init(k3, d_model, d_ff, dtype)
    return p


def mlp(p, x, ff_mask: Optional[jax.Array] = None):
    """SwiGLU if 'wg' present else GELU.  ``ff_mask`` (d_ff,) optionally
    zeroes hidden units — used by the supernet 'bottleneck' branch."""
    h = dense(p["wi"], x)
    if "wg" in p:
        h = jax.nn.silu(dense(p["wg"], x)) * h
    else:
        h = jax.nn.gelu(h)
    if ff_mask is not None:
        h = h * ff_mask.astype(h.dtype)
    return dense(p["wo"], h)


def cross_entropy(logits, labels, ignore_id=-1):
    """Mean token cross-entropy in fp32; labels==ignore_id are masked."""
    logits = logits.astype(jnp.float32)
    mask = (labels != ignore_id)
    safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)


def fused_cross_entropy(h, table, labels, ignore_id=-1, chunk=8192):
    """Unembed + cross-entropy fused over token chunks.

    Never materializes the full (B, S, V) fp32 logits: each chunk's logits
    are computed, reduced to (logsumexp, gold) scalars per token, and
    *recomputed* in the backward pass (jax.checkpoint).  At train_4k scale
    on the production mesh the naive path's logits are the dominant
    activation (e.g. qwen1.5: 1M tokens x 152k vocab x 4B = 617 GB global);
    this path caps the live logits at chunk x V.

    h: (B, S, d); table: (V, d); labels: (B, S).
    """
    b, s, d = h.shape
    t = b * s
    chunk = min(chunk, t)
    n_chunks = -(-t // chunk)
    pad = n_chunks * chunk - t
    x = h.reshape(t, d)
    y = labels.reshape(t)
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        y = jnp.pad(y, (0, pad), constant_values=ignore_id)
    x = x.reshape(n_chunks, chunk, d)
    y = y.reshape(n_chunks, chunk)

    @jax.checkpoint
    def chunk_nll(xc, yc):
        logits = jnp.einsum("td,vd->tv", xc, table).astype(jnp.float32)
        mask = yc != ignore_id
        safe = jnp.where(mask, yc, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe[:, None], axis=-1)[:, 0]
        return jnp.sum((logz - gold) * mask), jnp.sum(mask)

    def body(carry, xy):
        nll, cnt = chunk_nll(*xy)
        return (carry[0] + nll, carry[1] + cnt), None

    (nll, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.int32(0)),
                                 (x, y))
    return nll / jnp.maximum(cnt, 1)
