"""GShard/Switch-style top-k MoE with capacity-bounded gather dispatch.

Dispatch is gather/scatter based (O(T*k*d) data movement) rather than the
classic one-hot-einsum formulation (O(T*E*C*d) FLOPs) — at assigned-config
scale (1M tokens, 16 experts) the einsum dispatch would add ~7e18 flops of
pure bookkeeping.  The expert GEMM itself is a grouped matmul that maps to
the ``expert_gemm`` Pallas kernel on TPU.

Experts are sharded over the ``model`` mesh axis (expert parallelism); with
that sharding XLA turns the gather/scatter pair into the paper-standard
all-to-all exchange.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, mlp, mlp_init, uniform_init


def moe_init(key, cfg):
    kr, ke, ks = jax.random.split(key, 3)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff or cfg.d_ff
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": {"w": uniform_init(kr, (d, e), scale, cfg.jdtype)},
        "experts": {
            "wi": uniform_init(jax.random.fold_in(ke, 0), (e, d, f), scale,
                               cfg.jdtype),
            "wg": uniform_init(jax.random.fold_in(ke, 1), (e, d, f), scale,
                               cfg.jdtype),
            "wo": uniform_init(jax.random.fold_in(ke, 2), (e, f, d),
                               1.0 / math.sqrt(f), cfg.jdtype),
        },
    }
    if cfg.shared_expert:
        p["shared"] = mlp_init(ks, d, cfg.d_ff, cfg.jdtype)
    return p


def _capacity(tokens: int, num_experts: int, top_k: int, factor: float) -> int:
    c = int(math.ceil(tokens * top_k * factor / num_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly tiling


def expert_ffn(experts, x):
    """Grouped SwiGLU over (E, C, d) slots -> (E, C, d)."""
    h = jnp.einsum("ecd,edf->ecf", x, experts["wi"])
    g = jnp.einsum("ecd,edf->ecf", x, experts["wg"])
    h = jax.nn.silu(g) * h
    return jnp.einsum("ecf,efd->ecd", h, experts["wo"])


def moe_apply(p, x, cfg, *, ff_mask=None) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss).  ``ff_mask`` optionally narrows the
    expert hidden dim (supernet 'bottleneck' branch).

    Dispatches to the shard_map expert-parallel implementation when the
    launcher registered a mesh whose axes divide the expert/batch dims;
    otherwise runs the pure-GSPMD gather formulation below.
    """
    from repro.launch import policy
    mesh = policy.get_mesh()
    if mesh is not None and "model" in mesh.axis_names:
        m_size = mesh.shape["model"]
        d_size = policy.data_axis_size(mesh)
        if (cfg.num_experts % m_size == 0 and x.shape[0] % d_size == 0):
            return _moe_apply_shard_map(p, x, cfg, mesh, ff_mask=ff_mask)
    return _moe_apply_gather(p, x, cfg, ff_mask=ff_mask)


def _moe_apply_gather(p, x, cfg, *, ff_mask=None):
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.top_k
    cap = _capacity(t, e, k, cfg.capacity_factor)
    x2 = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", x2.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, k)          # (t, k)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance auxiliary loss.
    me = probs.mean(axis=0)                              # mean router prob
    ce = jnp.bincount(expert_idx.reshape(-1), length=e).astype(jnp.float32)
    ce = ce / (t * k)
    aux = e * jnp.sum(me * ce)

    # Sort-based dispatch: rank every (token, choice) within its expert via
    # one argsort over t*k routing decisions — O(t*k) memory, never
    # materializing the O(t*e) one-hot/cumsum bookkeeping (which costs
    # ~280 GB/device of temp at prefill_32k scale for granite's 32 experts).
    flat_expert = expert_idx.reshape(-1).astype(jnp.int32)       # (t*k,)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    starts = jnp.searchsorted(sorted_expert, jnp.arange(e, dtype=jnp.int32))
    rank_sorted = (jnp.arange(t * k, dtype=jnp.int32)
                   - starts[sorted_expert])
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(rank_sorted)
    kept = rank < cap
    slot = jnp.where(kept, flat_expert * cap + rank, e * cap)    # (t*k,)
    token_of_choice = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    # Scatter tokens into (E*C) slots, gather activations, run grouped GEMM.
    slot_token = jnp.zeros((e * cap + 1,), jnp.int32)
    slot_used = jnp.zeros((e * cap + 1,), dtype=x2.dtype)
    slot_token = slot_token.at[slot].set(token_of_choice, mode="drop")
    slot_used = slot_used.at[slot].set(1.0, mode="drop")
    expert_in = x2[slot_token[: e * cap]] * slot_used[: e * cap, None]
    expert_in = expert_in.reshape(e, cap, d)
    if ff_mask is not None:
        # narrow the expert hidden dim by masking (supernet bottleneck)
        h = jnp.einsum("ecd,edf->ecf", expert_in, p["experts"]["wi"])
        g = jnp.einsum("ecd,edf->ecf", expert_in, p["experts"]["wg"])
        h = jax.nn.silu(g) * h * ff_mask.astype(h.dtype)
        expert_out = jnp.einsum("ecf,efd->ecd", h, p["experts"]["wo"])
    else:
        expert_out = expert_ffn(p["experts"], expert_in)
    out_flat = expert_out.reshape(e * cap, d)
    out_flat = jnp.concatenate([out_flat, jnp.zeros((1, d), out_flat.dtype)])

    slot_tk = slot.reshape(t, k)
    y2 = jnp.zeros((t, d), x2.dtype)
    for j in range(k):
        y2 = y2 + (out_flat[slot_tk[:, j]]
                   * gate[:, j, None].astype(x2.dtype))

    if "shared" in p:
        y2 = y2 + mlp(p["shared"], x2)
    return y2.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# shard_map expert parallelism (GShard-style all-to-all)
# ---------------------------------------------------------------------------

def _local_dispatch(x2, router_w, e, k, cap):
    """Sort-based local routing.  x2: (t, d) local tokens.
    Returns (expert_in (e, cap, d), slot (t*k,), gate (t, k), aux)."""
    t = x2.shape[0]
    logits = jnp.einsum("td,de->te", x2.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, k)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

    me = probs.mean(axis=0)
    ce = jnp.bincount(expert_idx.reshape(-1), length=e).astype(jnp.float32)
    aux = e * jnp.sum(me * ce / (t * k))

    flat_expert = expert_idx.reshape(-1).astype(jnp.int32)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    starts = jnp.searchsorted(sorted_expert, jnp.arange(e, dtype=jnp.int32))
    rank_sorted = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_expert]
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(rank_sorted)
    kept = rank < cap
    slot = jnp.where(kept, flat_expert * cap + rank, e * cap)
    token_of_choice = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    slot_token = jnp.zeros((e * cap + 1,), jnp.int32)
    slot_used = jnp.zeros((e * cap + 1,), dtype=x2.dtype)
    slot_token = slot_token.at[slot].set(token_of_choice, mode="drop")
    slot_used = slot_used.at[slot].set(1.0, mode="drop")
    expert_in = (x2[slot_token[: e * cap]]
                 * slot_used[: e * cap, None]).reshape(e, cap, x2.shape[1])
    return expert_in, slot, gate, aux


def _moe_apply_shard_map(p, x, cfg, mesh, *, ff_mask=None):
    """Expert parallelism over the 'model' axis with explicit all-to-all.

    Per device: route the LOCAL tokens (local capacity), all-to-all the
    (e, cap, d) dispatch buffer over the model axis so each device holds its
    e/M experts' slots from every peer, run the grouped GEMM with
    FSDP-all-gathered expert weights, all-to-all back, combine locally.
    The paper-standard GShard communication pattern, explicit in the HLO.
    """
    from jax.sharding import PartitionSpec as P

    data_ax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    m = mesh.shape["model"]
    e, k = cfg.num_experts, cfg.top_k
    b, s, d = x.shape
    d_size = 1
    for a in data_ax:
        d_size *= mesh.shape[a]
    t_loc = (b // d_size) * s
    t_pad = -(-t_loc // m) * m            # pad so the model axis can split
    t_slice = t_pad // m                  # tokens routed per device
    cap = _capacity(t_slice, e, k, cfg.capacity_factor)
    experts = p["experts"]

    def body(x_loc, router_w, wi, wg, wo):
        # x_loc: (b_loc, s, d) — replicated over 'model', sharded over data;
        # each model column routes a distinct 1/M slice of the local tokens.
        bl, sl, _ = x_loc.shape
        x2 = x_loc.reshape(bl * sl, d)
        if t_pad != bl * sl:
            x2 = jnp.pad(x2, ((0, t_pad - bl * sl), (0, 0)))
        col = jax.lax.axis_index("model")
        xs = jax.lax.dynamic_slice(x2, (col * t_slice, 0), (t_slice, d))
        expert_in, slot, gate, aux = _local_dispatch(xs, router_w, e, k, cap)
        # experts <-> tokens exchange (the GShard all-to-all)
        ei = jax.lax.all_to_all(expert_in, "model", split_axis=0,
                                concat_axis=1, tiled=True)   # (e/M, M*cap, d)
        # FSDP-gather this layer's expert weights (d is the sharded dim)
        wi_g = jax.lax.all_gather(wi, data_ax, axis=1, tiled=True)
        wg_g = jax.lax.all_gather(wg, data_ax, axis=1, tiled=True)
        wo_g = jax.lax.all_gather(wo, data_ax, axis=2, tiled=True)
        h = jnp.einsum("ecd,edf->ecf", ei, wi_g)
        g = jnp.einsum("ecd,edf->ecf", ei, wg_g)
        h = jax.nn.silu(g) * h
        if ff_mask is not None:
            h = h * ff_mask.astype(h.dtype)
        eo = jnp.einsum("ecf,efd->ecd", h, wo_g)             # (e/M, M*cap, d)
        eo = jax.lax.all_to_all(eo, "model", split_axis=1,
                                concat_axis=0, tiled=True)   # (e, cap, d)
        out_flat = eo.reshape(e * cap, d)
        out_flat = jnp.concatenate(
            [out_flat, jnp.zeros((1, d), out_flat.dtype)])
        slot_tk = slot.reshape(t_slice, k)
        ys = jnp.zeros((t_slice, d), x2.dtype)
        for j in range(k):
            ys = ys + (out_flat[slot_tk[:, j]]
                       * gate[:, j, None].astype(x2.dtype))
        # reassemble the full local token range: each column contributes its
        # slice; psum over 'model' both combines and restores invariance.
        y2 = jnp.zeros((t_pad, d), x2.dtype)
        y2 = jax.lax.dynamic_update_slice(y2, ys, (col * t_slice, 0))
        y2 = jax.lax.psum(y2, "model")
        aux = jax.lax.pmean(aux, data_ax + ("model",))
        return y2[: bl * sl].reshape(bl, sl, d), aux

    from jax.experimental.shard_map import shard_map

    fsdp = data_ax if len(data_ax) > 1 else data_ax[0]
    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(data_ax, None, None), P(None, None),
                  P("model", fsdp, None), P("model", fsdp, None),
                  P("model", None, fsdp)),
        out_specs=(P(data_ax, None, None), P()),
    )(x, p["router"]["w"], experts["wi"], experts["wg"], experts["wo"])

    if "shared" in p:
        b_, s_, _ = x.shape
        y = y + mlp(p["shared"], x.reshape(b_ * s_, d)).reshape(b_, s_, d)
    return y, aux
