"""Mamba2 (SSD — state-space duality) block, pure JAX.

Follows the minimal SSD formulation of arXiv:2405.21060: the sequence is
processed in chunks; within a chunk the recurrence is materialized as a
(Q x Q) semiseparable attention-like matmul (MXU friendly), across chunks a
tiny ``lax.scan`` carries the (H, P, N) state.  The chunk computation is the
``ssd_scan`` Pallas kernel's target; this module doubles as its oracle.

Projections are SEPARATE dense layers (z/x/B/C/dt) rather than one fused
in_proj: slicing a tensor-parallel-sharded fused projection at non-shard-
aligned offsets (di, di+n, ...) forces GSPMD to re-replicate the full
activation on every layer — measured at ~1.3 GB/layer/device at train_4k
scale before the split (EXPERIMENTS.md §Perf).

Decode is the O(1) recurrent update: S <- exp(dt*A) S + dt * B (x) x.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dense_init, rmsnorm, rmsnorm_init, uniform_init

CHUNK = 128


def ssm_init(key, cfg):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 9)
    cw = 1.0 / math.sqrt(cfg.ssm_conv)
    return {
        "z_proj": dense_init(ks[0], d, di, cfg.jdtype),
        "x_proj": dense_init(ks[1], d, di, cfg.jdtype),
        "b_proj": dense_init(ks[2], d, n, cfg.jdtype),
        "c_proj": dense_init(ks[3], d, n, cfg.jdtype),
        "dt_proj": dense_init(ks[4], d, h, cfg.jdtype),
        "conv_x": {"w": uniform_init(ks[5], (cfg.ssm_conv, di), cw, cfg.jdtype),
                   "b": jnp.zeros((di,), cfg.jdtype)},
        "conv_b": {"w": uniform_init(ks[6], (cfg.ssm_conv, n), cw, cfg.jdtype),
                   "b": jnp.zeros((n,), cfg.jdtype)},
        "conv_c": {"w": uniform_init(ks[7], (cfg.ssm_conv, n), cw, cfg.jdtype),
                   "b": jnp.zeros((n,), cfg.jdtype)},
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "norm": rmsnorm_init(di, cfg.jdtype),
        "out_proj": dense_init(ks[8], di, d, cfg.jdtype),
    }


def _causal_conv(x, conv):
    """Depthwise causal conv over seq as K shifted adds.  x: (B, S, C)."""
    w, b = conv["w"], conv["b"]
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    s = x.shape[1]
    out = None
    for i in range(k):
        piece = pad[:, i: i + s, :] * w[i]
        out = piece if out is None else out + piece
    return jax.nn.silu(out + b)


def segsum(a):
    """log-space segment sums: out[..., i, j] = sum_{j<m<=i} a[..., m].
    a: (..., Q) -> (..., Q, Q), lower-triangular valid."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), dtype=bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a_head, b_mat, c_mat, chunk=CHUNK,
                initial_state=None, backend="xla"):
    """Chunked SSD scan.

    x: (B, S, H, P) raw head inputs;  dt: (B, S, H) (already softplus'd);
    a_head: (H,) negative decay;  b_mat, c_mat: (B, S, N) (single group).
    Returns (y: (B, S, H, P), final_state: (B, H, P, N)).
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    s_orig = s
    if s % chunk:
        # zero-pad: dt=0 on padded steps => decay exp(0)=1 and no input
        # contribution, so the recurrent state is unaffected
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // chunk
    xs = (x * dt[..., None]).reshape(bsz, nc, chunk, h, p).astype(jnp.float32)
    a = (dt * a_head[None, None, :]).reshape(bsz, nc, chunk, h)  # log decay
    bm = b_mat.reshape(bsz, nc, chunk, n).astype(jnp.float32)
    cm = c_mat.reshape(bsz, nc, chunk, n).astype(jnp.float32)

    if backend == "pallas":
        from repro.kernels import ops as kops
        y, final = kops.ssd_scan(xs, a, bm, cm, initial_state)
        y = y.reshape(bsz, s, h, p)[:, :s_orig]
        return y.astype(x.dtype), final

    a_cum = jnp.cumsum(a, axis=2)                        # (b, c, q, h)
    # 1) intra-chunk (diagonal blocks)
    l_mat = jnp.exp(segsum(jnp.moveaxis(a, -1, -2)))     # (b, c, h, q, q)
    y_diag = jnp.einsum("bcqn,bckn,bchqk,bckhp->bcqhp", cm, bm, l_mat, xs)
    # 2) per-chunk final states
    decay_states = jnp.exp(a_cum[:, :, -1:, :] - a_cum)  # (b, c, q, h)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", bm, decay_states, xs)
    # 3) inter-chunk recurrence (tiny scan over chunks)
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])            # (b, c, h)
    s0 = (jnp.zeros((bsz, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry   # emit the state *entering* the chunk

    final, prev_states = jax.lax.scan(
        step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)        # (b, c, h, p, n)
    # 4) state -> output within each chunk
    state_decay = jnp.exp(a_cum)                         # (b, c, q, h)
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", cm, prev_states, state_decay)
    y = (y_diag + y_off).reshape(bsz, s, h, p)[:, :s_orig]
    return y.astype(x.dtype), final


def ssm_forward(p, x, cfg, *, state_mask=None, head_mask=None,
                backend="xla"):
    """Full-sequence Mamba2 block.  x: (B, S, d) -> (B, S, d).
    ``state_mask`` (N,) / ``head_mask`` (H,) are supernet branch masks."""
    bsz, s, d = x.shape
    di, n, h, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z = dense(p["z_proj"], x)
    x_in = _causal_conv(dense(p["x_proj"], x), p["conv_x"])
    b_mat = _causal_conv(dense(p["b_proj"], x), p["conv_b"])
    c_mat = _causal_conv(dense(p["c_proj"], x), p["conv_c"])
    dt = dense(p["dt_proj"], x)
    x_in = x_in.reshape(bsz, s, h, pd)
    if state_mask is not None:
        b_mat = b_mat * state_mask.astype(b_mat.dtype)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a_head = -jnp.exp(p["A_log"])
    y, _ = ssd_chunked(x_in, dt, a_head, b_mat, c_mat, backend=backend)
    y = y + x_in.astype(jnp.float32) * p["D"][None, None, :, None]
    if head_mask is not None:
        y = y * head_mask.astype(y.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y)
    return dense(p["out_proj"], y)


def init_ssm_cache(batch, cfg, dtype):
    di, n = cfg.d_inner, cfg.ssm_state
    k = cfg.ssm_conv - 1
    return {
        "conv_x": jnp.zeros((batch, k, di), dtype),
        "conv_b": jnp.zeros((batch, k, n), dtype),
        "conv_c": jnp.zeros((batch, k, n), dtype),
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, n),
                           jnp.float32),
    }


def _conv_step(buf, xt, conv):
    """One-token depthwise conv against the rolling buffer.
    buf: (B, K-1, C), xt: (B, C) -> (out (B, C), new buf)."""
    w, b = conv["w"], conv["b"]
    full = jnp.concatenate([buf, xt[:, None, :]], axis=1)   # (B, K, C)
    out = jnp.einsum("bkc,kc->bc", full, w) + b
    return jax.nn.silu(out), full[:, 1:, :]


def ssm_decode_step(p, x, cache, cfg, *, state_mask=None, head_mask=None):
    """One-token recurrent update.  x: (B, 1, d)."""
    bsz = x.shape[0]
    di, n, h, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    x0 = x[:, 0]
    z = dense(p["z_proj"], x0)
    xt, new_cx = _conv_step(cache["conv_x"], dense(p["x_proj"], x0),
                            p["conv_x"])
    bt, new_cb = _conv_step(cache["conv_b"], dense(p["b_proj"], x0),
                            p["conv_b"])
    ct, new_cc = _conv_step(cache["conv_c"], dense(p["c_proj"], x0),
                            p["conv_c"])
    dt = dense(p["dt_proj"], x0)
    x_in = xt.reshape(bsz, h, pd)
    if state_mask is not None:
        bt = bt * state_mask.astype(bt.dtype)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B, H)
    a_head = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * a_head[None, :])                # (B, H)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, x_in.astype(jnp.float32),
                     bt.astype(jnp.float32))
    state = cache["state"] * decay[..., None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", ct.astype(jnp.float32), state)
    y = y + x_in.astype(jnp.float32) * p["D"][None, :, None]
    if head_mask is not None:
        y = y * head_mask.astype(y.dtype)[None, :, None]
    y = y.reshape(bsz, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y)
    out = dense(p["out_proj"], y)[:, None, :]
    return out, {"conv_x": new_cx, "conv_b": new_cb, "conv_c": new_cc,
                 "state": state}
