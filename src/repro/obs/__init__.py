"""`repro.obs` — zero-overhead observability for the federated engine.

Phase spans, JIT trace counters, resource gauges and structured
round-event sinks; bit-exactly invisible when disabled (the default).
See ``docs/observability.md`` for the span schema and usage.
"""
from repro.obs.backend import InstrumentedBackend
from repro.obs.gauges import (PeakLiveBytes, host_rss_bytes,
                              live_device_bytes, steady_mean)
from repro.obs.sinks import (JsonlSink, MemorySink, TableSink, event_dict,
                             make_sink, parse_sink_spec)
from repro.obs.telemetry import (COMM_FIELDS, NULL_TELEMETRY, PHASES,
                                 NullTelemetry, RoundEvent, Telemetry,
                                 TelemetryConfig, TelemetryResult, attach,
                                 innermost, traced)

__all__ = [
    "COMM_FIELDS",
    "InstrumentedBackend",
    "JsonlSink",
    "MemorySink",
    "NULL_TELEMETRY",
    "NullTelemetry",
    "PHASES",
    "PeakLiveBytes",
    "RoundEvent",
    "TableSink",
    "Telemetry",
    "TelemetryConfig",
    "TelemetryResult",
    "attach",
    "event_dict",
    "host_rss_bytes",
    "innermost",
    "live_device_bytes",
    "make_sink",
    "parse_sink_spec",
    "steady_mean",
    "traced",
]
