"""Resource gauges and timing helpers shared by telemetry and the
benchmark driver.

Before ``repro.obs`` every mode of ``benchmarks/fed_nas.py`` hand-rolled
its own peak-live-bytes probe and steady-state mean; these are the
single definitions now — the benchmark modes and the per-round telemetry
gauges both report through them, so "peak live device bytes" means the
same measurement everywhere it appears.

Everything here is stdlib + jax only (no psutil: host RSS comes from
``resource.getrusage``, with ``/proc/self/status`` preferred on Linux
because ru_maxrss is a lifetime peak, not the current footprint).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax


def live_device_bytes() -> int:
    """Total bytes of all currently-live jax device arrays."""
    return sum(a.nbytes for a in jax.live_arrays())


def host_rss_bytes() -> int:
    """Current process resident-set size in bytes (0 if unknowable).

    Prefers ``/proc/self/status`` (current VmRSS); falls back to
    ``resource.getrusage`` ru_maxrss (a lifetime *peak*, kilobytes on
    Linux) where /proc is unavailable.
    """
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmRSS:"):
                    return int(line.split()[1]) * 1024
    except OSError:
        pass
    try:
        import resource
        return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024
    except Exception:
        return 0


class PeakLiveBytes:
    """Track peak live device bytes across a run.

    ``sample`` matches the engine's per-round callback signature
    (``callback(gen, report)``), so an instance can be passed straight as
    ``FedEngine.run(callback=peak.sample)``; it also works with no
    arguments for manual probing.  ``baseline`` is sampled at
    construction; ``peak`` is the absolute high-water mark since then,
    and ``growth`` the peak *over the baseline* — the "peak live bytes"
    number the benchmark modes record, so arrays retained by earlier
    benchmark variants never bias later ones (exactly the old
    hand-rolled closures' semantics)."""

    def __init__(self):
        self.baseline = live_device_bytes()
        self.peak = self.baseline

    def sample(self, *_args) -> int:
        self.peak = max(self.peak, live_device_bytes())
        return self.peak

    @property
    def growth(self) -> int:
        return self.peak - self.baseline


def steady_mean(values: Sequence[float]) -> Optional[float]:
    """Steady-state mean: drop the first element (it pays JIT tracing /
    compilation) and average the rest; with a single element return it
    as-is; empty input returns None.  This is the exact expression every
    benchmark mode previously inlined."""
    if not values:
        return None
    if len(values) == 1:
        return float(values[0])
    return float(sum(values[1:]) / (len(values) - 1))
