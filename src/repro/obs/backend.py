"""InstrumentedBackend: phase spans applied around any execution backend.

The same decorating pattern as ``repro.comm.backend.CodecBackend`` —
implement the ``ExecutionBackend`` protocol, proxy the engine plumbing
(``name`` / ``dispatches`` / ``reset``), delegate the work.  The engine
wraps it *outermost* (``InstrumentedBackend(CodecBackend(backend))``)
so a ``fill_train`` span covers the whole backend call including codec
encode/decode, and the codec's own ``codec_encode``/``codec_decode``
spans nest beneath it in the recorded paths
(``"fill_train/codec_decode"``).

Like the codec wrapper, it is only constructed when telemetry is
enabled; disabled runs keep the exact pre-subsystem call path.
"""
from __future__ import annotations

from typing import Any, List, Sequence

import numpy as np

Params = Any


class InstrumentedBackend:
    """Wrap ``inner`` so every backend call runs under a telemetry span:
    ``fill_train`` for the training entry points, ``eval`` for the
    evaluation ones."""

    def __init__(self, inner, telemetry):
        self.inner = inner
        self.telemetry = telemetry

    # -- engine plumbing -----------------------------------------------------

    @property
    def name(self) -> str:
        return self.inner.name

    @property
    def dispatches(self) -> int:
        return self.inner.dispatches

    @dispatches.setter
    def dispatches(self, value: int) -> None:
        self.inner.dispatches = value

    def reset(self) -> None:
        reset = getattr(self.inner, "reset", None)
        if reset is not None:
            reset()

    # -- ExecutionBackend protocol -------------------------------------------

    def train_fill(self, master: Params, keys, groups, lr: float,
                   survivors=None) -> Params:
        with self.telemetry.span("fill_train"):
            return self.inner.train_fill(master, keys, groups, lr,
                                         survivors=survivors)

    def train_fedavg(self, params: Params, key, client_ids,
                     lr: float, survivors=None) -> Params:
        with self.telemetry.span("fill_train"):
            return self.inner.train_fedavg(params, key, client_ids, lr,
                                           survivors=survivors)

    def train_fedavg_population(self, params_list: Sequence[Params], keys,
                                client_ids, lr: float,
                                survivors=None) -> List[Params]:
        with self.telemetry.span("fill_train"):
            return self.inner.train_fedavg_population(
                params_list, keys, client_ids, lr, survivors=survivors)

    def eval_shared(self, params: Params, keys, client_ids,
                    survivors=None) -> np.ndarray:
        with self.telemetry.span("eval"):
            return self.inner.eval_shared(params, keys, client_ids,
                                          survivors=survivors)

    def eval_paired(self, params_list: Sequence[Params], keys,
                    client_ids, survivors=None) -> np.ndarray:
        with self.telemetry.span("eval"):
            return self.inner.eval_paired(params_list, keys, client_ids,
                                          survivors=survivors)
