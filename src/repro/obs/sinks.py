"""Round-event sinks: where telemetry's per-round records go.

Three built-ins, selected by ``TelemetryConfig.sink``:

  * ``"memory"`` — ring only (``MemorySink`` is always active as the
    ring behind ``EngineResult.telemetry.events``)
  * ``"jsonl:<path>"`` — append one JSON object per round, flushed per
    event so a crashed/killed run keeps everything up to its last
    completed round (this is the file CI uploads next to
    ``BENCH_engine.json``)
  * ``"table"`` — human-oriented terminal table, one row per round

All sinks consume the same ``RoundEvent`` dataclass; ``event_dict``
defines the JSON shape.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import sys
from typing import Any, Dict, Tuple


def parse_sink_spec(spec: str) -> Tuple[str, str]:
    """Validate and split a sink spec into ``(kind, arg)``.

    Raises ``ValueError`` for unknown kinds or a pathless jsonl spec —
    called from ``TelemetryConfig.__post_init__`` so bad specs fail at
    config construction, not mid-run.
    """
    if spec == "memory" or spec == "table":
        return spec, ""
    if spec.startswith("jsonl:"):
        path = spec[len("jsonl:"):]
        if not path:
            raise ValueError("jsonl sink needs a path: 'jsonl:<path>'")
        return "jsonl", path
    raise ValueError(
        f"unknown telemetry sink {spec!r} "
        "(expected 'memory', 'jsonl:<path>' or 'table')")


def event_dict(event) -> Dict[str, Any]:
    """A RoundEvent as a plain JSON-serializable dict."""
    return dataclasses.asdict(event)


class MemorySink:
    """Bounded in-memory ring of the most recent ``RoundEvent``s."""

    def __init__(self, ring: int):
        self.events = collections.deque(maxlen=ring)

    def emit(self, event) -> None:
        self.events.append(event)

    def reset(self) -> None:
        self.events.clear()


class JsonlSink:
    """One JSON object per round appended to ``path`` and flushed
    immediately (lazy-opened so merely constructing a config never
    touches the filesystem)."""

    def __init__(self, path: str):
        self.path = path
        self._fh = None

    def emit(self, event) -> None:
        if self._fh is None:
            self._fh = open(self.path, "a")
        json.dump(event_dict(event), self._fh)
        self._fh.write("\n")
        self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


class TableSink:
    """A terminal table, one row per round: round time, top span paths,
    recompiles and headline gauges."""

    _HEADER = (f"{'gen':>4} {'round_s':>8} {'fill_train':>10} {'eval':>8} "
               f"{'sample':>8} {'retrace':>7} {'live_MB':>8} {'up_MB':>8}")

    def __init__(self, stream=None):
        self.stream = stream if stream is not None else sys.stdout
        self._printed_header = False

    def emit(self, event) -> None:
        if not self._printed_header:
            print(self._HEADER, file=self.stream)
            print("-" * len(self._HEADER), file=self.stream)
            self._printed_header = True
        spans = event.spans

        def top(name: str) -> float:
            # a phase plus everything nested beneath it
            return sum(s for path, s in spans.items()
                       if path == name or path.startswith(name + "/"))

        live = event.gauges.get("live_device_bytes", 0) / 1e6
        up = event.comm.get("up_bytes", 0.0) / 1e6
        print(f"{event.gen:>4} {event.round_s:>8.3f} "
              f"{top('fill_train'):>10.3f} {top('eval'):>8.3f} "
              f"{top('sample'):>8.3f} {sum(event.recompiles.values()):>7d} "
              f"{live:>8.1f} {up:>8.2f}", file=self.stream)


def make_sink(spec: str, ring: int = 1024):
    """Construct the sink a validated spec names."""
    kind, arg = parse_sink_spec(spec)
    if kind == "memory":
        return MemorySink(ring)
    if kind == "jsonl":
        return JsonlSink(arg)
    return TableSink()
