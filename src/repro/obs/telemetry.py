"""`repro.obs` core: zero-overhead telemetry for the federated engine.

The paper's headline claim is *real-time* federated NAS, and every
direction the ROADMAP names next (async buffered aggregation, adaptive
``ServerPolicy``s, codec auto-tuning) feeds off recorded round signals —
where a round's time goes, whether a config silently retraces the fused
programs, how device/host memory behaves across a sweep.  This module
turns those questions into engine truth:

  * ``Telemetry`` — nestable **phase spans** (``sample``,
    ``availability``, ``download``, ``fill_train``, ``aggregate``,
    ``eval``, ``codec_encode``/``codec_decode``, ``host_fetch``)
    recorded as monotonic ``time.perf_counter`` durations and
    accumulated per round under their nesting path (e.g.
    ``"fill_train/codec_decode"``).  Spans double as
    ``jax.profiler.TraceAnnotation``s so a profiler capture shows the
    same phase structure the round events record.
  * ``RoundEvent`` — one structured record per federated round: span
    durations and call counts, **recompile deltas** (trace-count per
    jitted program, see ``traced``), **resource gauges** (live device
    bytes, host RSS, lazy-fleet materialization, stacked-store LRU
    hit/miss) and the round's **CommStats deltas** — pushed to the
    configured sink and kept in an in-memory ring.
  * ``traced`` — wraps the *pre-jit* Python callable of every backend
    program so each ``jax.jit`` trace increments a per-program counter
    (tracing runs the Python body; dispatches do not) and the program
    body is labeled with ``jax.named_scope``.  This is what makes the
    "fused = 2·gens + 1 dispatches, compiled once" invariant directly
    observable instead of trusted.
  * ``NULL_TELEMETRY`` — the disabled path.  ``FedEngine`` only
    constructs a real ``Telemetry`` (and the ``InstrumentedBackend``
    wrapper) when ``RunConfig.telemetry`` is enabled; everything else
    sees this shared no-op object whose spans are empty context
    managers.  Telemetry is therefore *bit-exactly* invisible when off
    — no numeric path changes, no extra dispatches — which
    ``tests/test_obs.py`` pins across every backend × fused pair.

Nothing here imports ``repro.engine`` — the engine depends on ``obs``,
never the reverse — so the gauges read engine state duck-typed
(``clients.materialized``, ``backend.cache_stats``, ...).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import os
import time
from typing import Any, Dict, List, Optional

import jax

from repro.obs.gauges import host_rss_bytes, live_device_bytes
from repro.obs.sinks import MemorySink, make_sink, parse_sink_spec

# The span vocabulary (nesting paths join these with "/"):
#   sample       participant / client-group / offspring sampling
#   availability the ClientSimulator round draw
#   download     host->device staging of stacked client shards
#   fill_train   a backend training call (fill-train / FedAvg)
#   aggregate    server-side NSGA-II selection bookkeeping
#   eval         a backend evaluation call
#   codec_encode uplink codec compression of the aggregated update
#   codec_decode downlink codec roundtrip of a broadcast payload
#   host_fetch   the per-generation device_get of fused eval counts
PHASES = ("sample", "availability", "download", "fill_train", "aggregate",
          "eval", "codec_encode", "codec_decode", "host_fetch")

# CommStats fields whose per-round deltas every RoundEvent carries
COMM_FIELDS = ("down_bytes", "up_bytes", "down_wire_bytes", "up_wire_bytes",
               "eval_down_bytes", "eval_up_bytes", "wasted_down_bytes",
               "wasted_down_wire_bytes", "client_train_passes")


@dataclasses.dataclass
class TelemetryConfig:
    """Every telemetry knob, validated at construction (like the rest of
    ``RunConfig``).  The default ``RunConfig.telemetry = None`` means
    *off* — constructing this object means *on* unless ``enabled=False``.

      * ``sink`` — where round events go beyond the in-memory ring:
        ``"memory"`` (ring only), ``"jsonl:<path>"`` (one JSON object
        per round, appended live) or ``"table"`` (a terminal table row
        per round).
      * ``ring`` — how many ``RoundEvent``s the in-memory ring retains
        (``EngineResult.telemetry.events``); older rounds fall off.
      * ``gauges`` — sample per-round resource gauges (live device
        bytes, host RSS, fleet/cache counters).  Off leaves the gauges
        dict empty but keeps spans/recompiles/comm.
      * ``profiler_dir`` — when set, the whole ``run()`` executes under
        ``jax.profiler.trace(profiler_dir)``: open the captured trace in
        TensorBoard/Perfetto and the ``TraceAnnotation`` spans plus the
        ``jax.named_scope`` labels inside the fused programs name what
        you see.
      * ``annotations`` — emit a ``jax.profiler.TraceAnnotation`` per
        span (cheap host-side TraceMe; only visible inside a profiler
        capture)."""
    enabled: bool = True
    sink: str = "memory"
    ring: int = 1024
    gauges: bool = True
    profiler_dir: Optional[str] = None
    annotations: bool = True

    def __post_init__(self):
        if self.ring < 1:
            raise ValueError(f"ring must be >= 1, got {self.ring}")
        parse_sink_spec(self.sink)   # unknown sink specs fail here


@dataclasses.dataclass
class RoundEvent:
    """One federated round, as telemetry saw it.

    ``spans`` maps nesting paths (``"fill_train/download"``) to summed
    seconds this round; ``span_counts`` the number of times each path
    was entered.  ``recompiles`` holds trace-count *deltas* — a jitted
    program that (re)compiled this round appears with the number of new
    traces, steady-state rounds carry an empty dict.  ``gauges`` are
    point-in-time resource samples at round end; ``comm`` the round's
    ``CommStats`` field deltas."""
    gen: int
    round_s: float
    spans: Dict[str, float]
    span_counts: Dict[str, int]
    recompiles: Dict[str, int]
    gauges: Dict[str, Any]
    comm: Dict[str, float]


@dataclasses.dataclass
class TelemetryResult:
    """What ``EngineResult.telemetry`` carries after a telemetry-enabled
    run: the ring of ``RoundEvent``s plus the final per-program trace
    counts."""
    events: List[RoundEvent]
    trace_counts: Dict[str, int]

    def phase_totals(self) -> Dict[str, float]:
        """Total seconds per span path across all retained rounds."""
        out: Dict[str, float] = {}
        for e in self.events:
            for path, s in e.spans.items():
                out[path] = out.get(path, 0.0) + s
        return out


def traced(name: str, counts: Dict[str, int], fn):
    """Wrap a pre-``jax.jit`` Python callable so every trace increments
    ``counts[name]`` and the traced body sits under
    ``jax.named_scope(name)``.  Tracing runs the Python function;
    cached dispatches do not — so the counter is a faithful
    (re)compilation count per program, at zero dispatch cost.  The
    ``named_scope`` labels the program in profiler captures and HLO
    dumps; it never changes numerics."""

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        counts[name] = counts.get(name, 0) + 1
        with jax.named_scope(name):
            return fn(*args, **kwargs)

    return wrapper


def innermost(backend):
    """The raw execution backend under any wrapper chain
    (``InstrumentedBackend`` -> ``CodecBackend`` -> backend)."""
    while hasattr(backend, "inner"):
        backend = backend.inner
    return backend


def attach(backend, telemetry) -> None:
    """Point every layer of a backend wrapper chain at ``telemetry``
    (each layer defaults to ``NULL_TELEMETRY`` as a class attribute)."""
    while backend is not None:
        backend.telemetry = telemetry
        backend = getattr(backend, "inner", None)


class _NullSpan:
    """A context manager that does nothing, shared by every
    ``NULL_TELEMETRY.span`` call."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTelemetry:
    """The disabled telemetry object: every hook is a no-op, every span
    an empty context manager.  One shared instance (``NULL_TELEMETRY``)
    serves the engine, every strategy and every backend layer, so the
    telemetry-off hot path costs a single attribute lookup per hook."""
    __slots__ = ()
    enabled = False

    def span(self, name: str):
        return _NULL_SPAN

    def start_run(self, engine) -> None:
        pass

    def end_round(self, gen: int, round_s: float, engine) -> None:
        pass

    def run_capture(self):
        return contextlib.nullcontext()

    def result(self, engine) -> None:
        return None


NULL_TELEMETRY = NullTelemetry()


class _Span:
    __slots__ = ("tel", "name", "t0", "ta")

    def __init__(self, tel: "Telemetry", name: str):
        self.tel = tel
        self.name = name

    def __enter__(self):
        tel = self.tel
        if tel.annotations:
            self.ta = jax.profiler.TraceAnnotation(self.name)
            self.ta.__enter__()
        else:
            self.ta = None
        tel._stack.append(self.name)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dt = time.perf_counter() - self.t0
        tel = self.tel
        path = "/".join(tel._stack)
        tel._spans[path] = tel._spans.get(path, 0.0) + dt
        tel._counts[path] = tel._counts.get(path, 0) + 1
        tel._stack.pop()
        if self.ta is not None:
            self.ta.__exit__(*exc)
        return False


class Telemetry:
    """The live telemetry object of one engine.

    ``FedEngine`` owns exactly one (when ``RunConfig.telemetry`` is
    enabled), shares it with every backend layer (``obs.attach``) and
    drives the run lifecycle: ``start_run`` resets all state (run
    re-entrancy), ``span`` times a phase on the shared nesting stack,
    ``end_round`` assembles the round's ``RoundEvent`` and pushes it to
    the ring + sink, ``result`` returns the ``TelemetryResult`` stamped
    onto ``EngineResult``."""

    enabled = True

    def __init__(self, cfg: TelemetryConfig):
        self.cfg = cfg
        self.annotations = cfg.annotations
        self.ring = MemorySink(cfg.ring)
        self.sink = (None if cfg.sink == "memory"
                     else make_sink(cfg.sink, ring=cfg.ring))
        self._stack: List[str] = []
        self._spans: Dict[str, float] = {}
        self._counts: Dict[str, int] = {}
        self._tc_snap: Dict[str, int] = {}
        self._comm_snap: Dict[str, float] = {}
        self._peak_live = 0

    # -- lifecycle -----------------------------------------------------------

    def start_run(self, engine) -> None:
        """Reset per-run state; snapshot trace counts so pre-run traces
        (a backend reused across runs) are not booked to round 1."""
        self.ring.reset()
        self._stack = []
        self._spans = {}
        self._counts = {}
        self._peak_live = 0
        self._tc_snap = dict(self._trace_counts(engine))
        self._comm_snap = {f: 0.0 for f in COMM_FIELDS}

    def span(self, name: str) -> _Span:
        return _Span(self, name)

    def run_capture(self):
        """The profiler capture context for one ``run()`` —
        ``jax.profiler.trace(profiler_dir)`` when configured, a no-op
        otherwise."""
        if self.cfg.profiler_dir:
            os.makedirs(self.cfg.profiler_dir, exist_ok=True)
            return jax.profiler.trace(self.cfg.profiler_dir)
        return contextlib.nullcontext()

    def end_round(self, gen: int, round_s: float, engine) -> RoundEvent:
        """Assemble and emit this round's event, then reset the span
        accumulators for the next round."""
        tc = dict(self._trace_counts(engine))
        recompiles = {k: v - self._tc_snap.get(k, 0) for k, v in tc.items()
                      if v != self._tc_snap.get(k, 0)}
        self._tc_snap = tc
        comm = {}
        for f in COMM_FIELDS:
            v = float(getattr(engine.stats, f, 0.0))
            comm[f] = v - self._comm_snap.get(f, 0.0)
            self._comm_snap[f] = v
        event = RoundEvent(gen=gen, round_s=round_s,
                           spans=self._spans, span_counts=self._counts,
                           recompiles=recompiles,
                           gauges=self._gauges(engine), comm=comm)
        self._spans = {}
        self._counts = {}
        self.ring.emit(event)
        if self.sink is not None:
            self.sink.emit(event)
        return event

    def result(self, engine) -> TelemetryResult:
        return TelemetryResult(events=list(self.ring.events),
                               trace_counts=dict(self._trace_counts(engine)))

    # -- internals -----------------------------------------------------------

    @staticmethod
    def _trace_counts(engine) -> Dict[str, int]:
        return getattr(innermost(engine.backend), "trace_counts", {})

    def _gauges(self, engine) -> Dict[str, Any]:
        if not self.cfg.gauges:
            return {}
        live = live_device_bytes()
        self._peak_live = max(self._peak_live, live)
        out: Dict[str, Any] = {
            "live_device_bytes": live,
            "peak_live_device_bytes": self._peak_live,
            "host_rss_bytes": host_rss_bytes(),
        }
        clients = getattr(engine, "clients", None)
        materialized = getattr(clients, "materialized", None)
        if materialized is not None:     # lazy ClientFleet only
            out["clients_materialized"] = materialized
            out["clients_cached"] = getattr(clients, "cached", None)
            out["fleet_hits"] = getattr(clients, "hits", None)
        cache_stats = getattr(innermost(engine.backend), "cache_stats", None)
        if cache_stats is not None:      # stacked (vmap/mesh) backends only
            out.update(cache_stats)
        return out
