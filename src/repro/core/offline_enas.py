"""Offline federated evolutionary NAS — the paper's comparison baseline
(Section IV.G, following Zhu & Jin 2019 [7]).

Differences from the real-time method, reproduced faithfully:
  * every offspring model is REINITIALIZED and trained from scratch;
  * every client trains EVERY individual (N training passes per client per
    generation, vs 1 for the real-time method);
  * each individual is a standalone model aggregated with plain FedAvg —
    there is no shared master, no fill-aggregation, no weight inheritance.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence

import jax
import numpy as np

from repro.core import choice, nsga2
from repro.core.aggregate import fedavg
from repro.core.double_sampling import sample_participants, \
    sample_population_keys
from repro.core.federated import make_client_update, make_evaluator, \
    weighted_test_error
from repro.core.rt_enas import BYTES_PER_PARAM, CommStats, RunConfig
from repro.core.supernet import SupernetAPI
from repro.data.pipeline import ClientDataset
from repro.optim import round_decay


def run(api: SupernetAPI, clients: Sequence[ClientDataset],
        run_cfg: RunConfig) -> Dict:
    rng = np.random.default_rng(run_cfg.seed)
    update = make_client_update(api, run_cfg.local_epochs, run_cfg.momentum)
    evaluate = make_evaluator(api)
    stats = CommStats()

    parents = sample_population_keys(rng, run_cfg.population, api.num_blocks)
    parent_objs = None
    history: Dict[str, List] = {"gen": [], "objs": [], "parent_keys": [],
                                "best_err": [], "down_gb": [], "up_gb": [],
                                "train_passes": [], "wall_s": []}
    t0 = time.time()
    reinit_seed = 1000

    def train_and_eval(keys, participants, lr):
        nonlocal reinit_seed
        objs = []
        part_clients = [clients[int(i)] for i in participants]
        for key in keys:
            reinit_seed += 1
            # REINITIALIZED from scratch — the paper's central criticism
            params = api.init(jax.random.PRNGKey(reinit_seed))
            payload = api.payload_params(key)
            jkey = np.asarray(key, np.int32)
            uploads = []
            for c in part_clients:                      # every client trains
                stats.add_download(payload)
                xb, yb = c.train
                uploads.append((update(params, jkey, xb, yb, lr), c.weight))
                stats.add_upload(payload)
                stats.client_train_passes += 1
            params = fedavg(uploads)
            stats.add_download(payload, copies=len(part_clients))  # for eval
            err = weighted_test_error(evaluate, params, jkey, part_clients)
            objs.append([err, api.flops(key)])
        return np.asarray(objs, dtype=float)

    for gen in range(1, run_cfg.generations + 1):
        lr = float(round_decay(run_cfg.lr0, run_cfg.lr_decay, gen - 1))
        participants = sample_participants(rng, len(clients),
                                           run_cfg.participation)
        if parent_objs is None:
            parent_objs = train_and_eval(parents, participants, lr)
        offspring = choice.make_offspring(rng, parents, run_cfg.population,
                                          run_cfg.crossover, run_cfg.mutation)
        off_objs = train_and_eval(offspring, participants, lr)

        combined = list(parents) + list(offspring)
        objs = np.concatenate([parent_objs, off_objs], axis=0)
        sel = nsga2.select(objs, run_cfg.population)
        parents = [combined[i] for i in sel]
        parent_objs = objs[sel]

        history["gen"].append(gen)
        history["objs"].append(objs)
        history["parent_keys"].append([k.copy() for k in parents])
        history["best_err"].append(float(objs[sel][:, 0].min()))
        history["down_gb"].append(stats.down_bytes / 1e9)
        history["up_gb"].append(stats.up_bytes / 1e9)
        history["train_passes"].append(stats.client_train_passes)
        history["wall_s"].append(time.time() - t0)

    history["stats"] = stats
    return history
