"""Offline federated evolutionary NAS — the paper's comparison baseline
(Section IV.G, following Zhu & Jin 2019 [7]).

Compatibility shim over ``repro.engine`` (``FedEngine`` + ``OfflineNas``
strategy): every offspring model is REINITIALIZED and trained from
scratch, every client trains EVERY individual, and each individual is a
standalone model aggregated with plain FedAvg — no shared master, no
fill-aggregation, no weight inheritance.
"""
from __future__ import annotations

from typing import Dict, Sequence

from repro.core.supernet import SupernetAPI
from repro.data.pipeline import ClientDataset
from repro.engine.types import RunConfig


def run(api: SupernetAPI, clients: Sequence[ClientDataset],
        run_cfg: RunConfig) -> Dict:
    """One-call offline-baseline run (legacy API; history dict kept)."""
    from repro.engine import FedEngine, OfflineNas

    return FedEngine(api, clients, run_cfg,
                     strategy=OfflineNas()).run().history()
