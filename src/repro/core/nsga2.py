"""NSGA-II (Deb et al. 2000) — elitist non-dominated sorting + crowding.

Pure numpy; objectives are minimized (the runtime passes test *error* and
FLOPs).  Complexity matches the reference algorithm: O(m N^2) sorting,
O(m N log N) crowding.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


def dominates(a: np.ndarray, b: np.ndarray) -> bool:
    """a Pareto-dominates b (all <=, at least one <)."""
    return bool(np.all(a <= b) and np.any(a < b))


def fast_non_dominated_sort(objs: np.ndarray) -> List[List[int]]:
    """objs: (N, m).  Returns fronts as lists of indices, best first."""
    n = len(objs)
    s = [[] for _ in range(n)]        # solutions i dominates
    counts = np.zeros(n, dtype=int)   # how many dominate i
    fronts: List[List[int]] = [[]]
    for i in range(n):
        for j in range(n):
            if i == j:
                continue
            if dominates(objs[i], objs[j]):
                s[i].append(j)
            elif dominates(objs[j], objs[i]):
                counts[i] += 1
        if counts[i] == 0:
            fronts[0].append(i)
    k = 0
    while fronts[k]:
        nxt = []
        for i in fronts[k]:
            for j in s[i]:
                counts[j] -= 1
                if counts[j] == 0:
                    nxt.append(j)
        k += 1
        fronts.append(nxt)
    return fronts[:-1]


def crowding_distance(objs: np.ndarray, front: Sequence[int]) -> np.ndarray:
    """Crowding distance of each member of one front."""
    f = np.asarray(front)
    n, m = len(f), objs.shape[1]
    dist = np.zeros(n)
    if n <= 2:
        dist[:] = np.inf
        return dist
    for k in range(m):
        order = np.argsort(objs[f, k], kind="stable")
        vals = objs[f[order], k]
        span = vals[-1] - vals[0]
        dist[order[0]] = dist[order[-1]] = np.inf
        if span <= 0:
            continue
        dist[order[1:-1]] += (vals[2:] - vals[:-2]) / span
    return dist


def select(objs: np.ndarray, n_select: int) -> List[int]:
    """Environmental selection: fronts in order, crowding-distance ties."""
    chosen: List[int] = []
    for front in fast_non_dominated_sort(objs):
        if len(chosen) + len(front) <= n_select:
            chosen.extend(front)
        else:
            dist = crowding_distance(objs, front)
            order = np.argsort(-dist, kind="stable")
            need = n_select - len(chosen)
            chosen.extend([front[i] for i in order[:need]])
            break
    return chosen


def knee_point(objs: np.ndarray, front: Sequence[int]) -> int:
    """Knee = max distance to the extreme-point chord (paper Section III.C
    picks knee solutions for deployment)."""
    f = np.asarray(front)
    pts = objs[f].astype(float)
    lo, hi = pts.min(axis=0), pts.max(axis=0)
    span = np.where(hi > lo, hi - lo, 1.0)
    norm = (pts - lo) / span
    a = norm[np.argmin(norm[:, 0])]
    b = norm[np.argmin(norm[:, 1])]
    ab = b - a
    denom = np.linalg.norm(ab) + 1e-12
    cross = np.abs(ab[0] * (a[1] - norm[:, 1]) - ab[1] * (a[0] - norm[:, 0]))
    return int(f[np.argmax(cross / denom)])
