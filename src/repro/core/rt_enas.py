"""Real-time federated evolutionary NAS — the paper's Algorithm 4.

One NSGA-II generation == one federated communication round:

  t == 1: train parent sub-models (double-sampled), fill-aggregate;
  every t: breed offspring keys, train offspring sub-models (weights
  inherited from the master — never reinitialized), fill-aggregate,
  evaluate all 2N sub-models on every participating client (master +
  choice keys downloaded once), NSGA-II environmental selection.

Communication and client-compute costs are accounted per round so the
paper's efficiency claims (Section IV.G) can be validated quantitatively.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core import choice, nsga2
from repro.core.aggregate import fill_aggregate
from repro.core.double_sampling import (
    sample_client_groups, sample_participants, sample_population_keys,
)
from repro.core.federated import make_client_update, make_evaluator, \
    weighted_test_error
from repro.core.supernet import SupernetAPI
from repro.data.pipeline import ClientDataset
from repro.optim import round_decay

BYTES_PER_PARAM = 4


@dataclasses.dataclass
class RunConfig:
    population: int = 10
    generations: int = 500
    participation: float = 1.0          # C in the paper
    lr0: float = 0.1
    lr_decay: float = 0.995
    momentum: float = 0.5
    local_epochs: int = 1
    crossover: float = 0.9
    mutation: float = 0.1
    seed: int = 0
    aggregate_backend: str = "xla"      # 'pallas' routes Algorithm 3 to the kernel


@dataclasses.dataclass
class CommStats:
    down_bytes: float = 0.0
    up_bytes: float = 0.0
    client_train_passes: int = 0

    def add_download(self, params: int, copies: int = 1):
        self.down_bytes += BYTES_PER_PARAM * params * copies

    def add_upload(self, params: int, copies: int = 1):
        self.up_bytes += BYTES_PER_PARAM * params * copies


def _train_generation(api: SupernetAPI, master, keys, groups,
                      clients: Sequence[ClientDataset], update, lr,
                      stats: CommStats, run_cfg: RunConfig,
                      download_models: bool):
    """Train each individual's sub-model on its client group and
    fill-aggregate the uploads into the master (Algorithm 3)."""
    uploads = []
    for key, group in zip(keys, groups):
        payload = api.payload_params(key)
        jkey = np.asarray(key, np.int32)
        for cid in group:
            c = clients[int(cid)]
            if download_models:
                stats.add_download(payload)      # theta^q + key (t == 1)
            xb, yb = c.train
            p_k = update(master, jkey, xb, yb, lr)
            mask = api.trained_mask(p_k, key)
            uploads.append((p_k, mask, c.weight))
            stats.add_upload(payload)
            stats.client_train_passes += 1
    if uploads:
        master = fill_aggregate(master, uploads,
                                backend=run_cfg.aggregate_backend)
    return master


def run(api: SupernetAPI, clients: Sequence[ClientDataset],
        run_cfg: RunConfig,
        callback: Optional[Callable[[int, Dict], None]] = None) -> Dict:
    rng = np.random.default_rng(run_cfg.seed)
    master = api.init(jax.random.PRNGKey(run_cfg.seed))
    update = make_client_update(api, run_cfg.local_epochs, run_cfg.momentum)
    evaluate = make_evaluator(api)
    stats = CommStats()
    master_size = api.master_params()

    parents = sample_population_keys(rng, run_cfg.population, api.num_blocks)
    history: Dict[str, List] = {"gen": [], "objs": [], "parent_keys": [],
                                "best_err": [], "knee_err": [], "best_key": [],
                                "knee_key": [], "down_gb": [], "up_gb": [],
                                "train_passes": [], "wall_s": []}
    t0 = time.time()

    for gen in range(1, run_cfg.generations + 1):
        lr = float(round_decay(run_cfg.lr0, run_cfg.lr_decay, gen - 1))
        participants = sample_participants(rng, len(clients),
                                           run_cfg.participation)

        # --- t == 1 only: train the parent sub-models (Algorithm 4 l.15-26)
        if gen == 1:
            groups = sample_client_groups(rng, participants,
                                          run_cfg.population)
            master = _train_generation(api, master, parents, groups, clients,
                                       update, lr, stats, run_cfg,
                                       download_models=True)

        # --- offspring: inherit weights, never reinitialize (l.27-41)
        offspring = choice.make_offspring(rng, parents, run_cfg.population,
                                          run_cfg.crossover, run_cfg.mutation)
        groups = sample_client_groups(rng, participants, run_cfg.population)
        master = _train_generation(api, master, offspring, groups, clients,
                                   update, lr, stats, run_cfg,
                                   download_models=(gen == 1))

        # --- fitness: master + all 2N keys to every participant (l.43-49)
        combined = list(parents) + list(offspring)
        stats.add_download(master_size, copies=len(participants))
        part_clients = [clients[int(i)] for i in participants]
        errs = np.array([weighted_test_error(evaluate, master,
                                             np.asarray(k, np.int32),
                                             part_clients)
                         for k in combined])
        fl = np.array([api.flops(k) for k in combined], dtype=float)
        objs = np.stack([errs, fl], axis=1)

        # --- NSGA-II environmental selection (l.50-53)
        sel = nsga2.select(objs, run_cfg.population)
        parents = [combined[i] for i in sel]

        front0 = nsga2.fast_non_dominated_sort(objs[sel])[0]
        knee_local = nsga2.knee_point(objs[sel], front0)
        best_local = sel[int(np.argmin(objs[sel][:, 0]))]

        history["gen"].append(gen)
        history["objs"].append(objs)
        history["parent_keys"].append([k.copy() for k in parents])
        history["best_err"].append(float(objs[best_local, 0]))
        history["best_key"].append(combined[best_local].copy())
        history["knee_err"].append(float(objs[sel][knee_local, 0]))
        history["knee_key"].append(combined[sel[knee_local]].copy())
        history["down_gb"].append(stats.down_bytes / 1e9)
        history["up_gb"].append(stats.up_bytes / 1e9)
        history["train_passes"].append(stats.client_train_passes)
        history["wall_s"].append(time.time() - t0)
        if callback:
            callback(gen, history)

    history["final_master"] = master
    history["stats"] = stats
    return history
