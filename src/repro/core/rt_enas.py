"""Real-time federated evolutionary NAS — the paper's Algorithm 4.

Compatibility shim: the round loop now lives in ``repro.engine``
(``FedEngine`` + ``RealTimeNas`` strategy + a pluggable execution
backend).  ``run`` keeps the pre-engine signature and returns the same
history dict; new code should use ``repro.engine.FedEngine`` directly,
which also exposes the vectorized ``backend="vmap"`` execution path and a
typed ``RoundReport`` history.

``RunConfig`` and ``CommStats`` are re-exported from
``repro.engine.types`` (their new home).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Sequence

from repro.core.supernet import SupernetAPI
from repro.data.pipeline import ClientDataset
from repro.engine.types import BYTES_PER_PARAM, CommStats, RunConfig  # noqa: F401 (compat re-exports)


def run(api: SupernetAPI, clients: Sequence[ClientDataset],
        run_cfg: RunConfig,
        callback: Optional[Callable[[int, Dict], None]] = None) -> Dict:
    """One-call Algorithm 4 run (legacy API; history dict layout kept)."""
    from repro.engine import FedEngine, RealTimeNas
    from repro.engine.types import append_report

    engine = FedEngine(api, clients, run_cfg, strategy=RealTimeNas())
    # like the legacy loop, the dict handed to the callback each round IS
    # the returned history, gaining final_master/stats after the last round
    live: Dict = {}

    def cb(gen, report):
        append_report(live, report)
        if callback is not None:
            callback(gen, live)

    result = engine.run(callback=cb)
    live.update(result.extras)
    live["stats"] = result.stats
    return live
