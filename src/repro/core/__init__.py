"""The paper's primary contribution: real-time federated evolutionary NAS
(double-sampling + fill-aggregation + NSGA-II in one communication round)."""
from repro.core import (
    aggregate, choice, double_sampling, federated, flops, nsga2,
    offline_enas, rt_enas, supernet,
)
from repro.core.rt_enas import CommStats, RunConfig
from repro.core.supernet import SupernetAPI, make_api

__all__ = [
    "aggregate", "choice", "double_sampling", "federated", "flops", "nsga2",
    "offline_enas", "rt_enas", "supernet", "CommStats", "RunConfig",
    "SupernetAPI", "make_api",
]
