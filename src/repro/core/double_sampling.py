"""Double-sampling (paper Section III.B, contribution 1).

(a) model sampling — one choice key per individual samples a sub-network of
    the master model;
(b) client sampling — the m = C*K participating clients are partitioned
    WITHOUT replacement into N groups of L = floor(m/N); group g trains the
    sub-model of individual g, so every client trains exactly one sub-model
    exactly once per generation.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core import choice


def sample_participants(rng: np.random.Generator, total_clients: int,
                        participation: float) -> np.ndarray:
    """Select m = C*K participating clients for this round."""
    m = max(1, int(round(participation * total_clients)))
    return rng.permutation(total_clients)[:m]


def sample_client_groups(rng: np.random.Generator, participants: np.ndarray,
                         n_individuals: int,
                         strict: bool = False) -> List[np.ndarray]:
    """Partition participants into N disjoint groups of L = floor(m/N).

    The paper assumes m >= N (#clients >= population size); in that
    regime clients beyond N*L idle this round, matching the floor in the
    paper.  Under real-time availability (`ClientSimConfig`) fewer than
    N clients may show up, so instead of failing the round degrades
    gracefully: each of the first m groups gets one client and the rest
    stay empty.  An empty group trains nobody, so its individual's
    blocks are simply *filled* from the previous master during
    aggregation — exactly Algorithm 3's semantics for untrained
    branches — and with m == 0 the whole round leaves the master
    untouched.

    ``strict=True`` restores the legacy m >= N requirement: a fully
    synchronous run (no availability simulation) that is short of
    clients is a *misconfiguration*, not churn, and should fail loudly
    rather than silently search over mostly-empty groups.
    """
    m = len(participants)
    if strict and m < n_individuals:
        raise ValueError(f"need >= {n_individuals} clients, got {m}")
    perm = rng.permutation(participants)
    if m >= n_individuals:
        l_per = m // n_individuals
        return [perm[g * l_per:(g + 1) * l_per]
                for g in range(n_individuals)]
    return [perm[g:g + 1] for g in range(n_individuals)]


def sample_population_keys(rng: np.random.Generator, n: int,
                           num_blocks: int) -> List[np.ndarray]:
    return [choice.random_key(rng, num_blocks) for _ in range(n)]
