"""Double-sampling (paper Section III.B, contribution 1).

(a) model sampling — one choice key per individual samples a sub-network of
    the master model;
(b) client sampling — the m = C*K participating clients are partitioned
    WITHOUT replacement into N groups of L = floor(m/N); group g trains the
    sub-model of individual g, so every client trains exactly one sub-model
    exactly once per generation.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core import choice


def sample_participants(rng: np.random.Generator, total_clients: int,
                        participation: float) -> np.ndarray:
    """Select m = C*K participating clients for this round."""
    m = max(1, int(round(participation * total_clients)))
    return rng.permutation(total_clients)[:m]


def sample_client_groups(rng: np.random.Generator, participants: np.ndarray,
                         n_individuals: int) -> List[np.ndarray]:
    """Partition participants into N disjoint groups of L = floor(m/N).

    Requires m >= N (paper assumes #clients >= population size).  Clients
    beyond N*L idle this round, matching the floor in the paper.
    """
    m = len(participants)
    if m < n_individuals:
        raise ValueError(f"need >= {n_individuals} clients, got {m}")
    l_per = m // n_individuals
    perm = rng.permutation(participants)
    return [perm[g * l_per:(g + 1) * l_per] for g in range(n_individuals)]


def sample_population_keys(rng: np.random.Generator, n: int,
                           num_blocks: int) -> List[np.ndarray]:
    return [choice.random_key(rng, num_blocks) for _ in range(n)]
