"""Choice-key encoding for the paper's search space (Section III.A).

A sub-network of the master model is identified by one 2-bit code per
choice block: [0,0]=identity(0), [0,1]=residual(1), [1,0]=inverted(2),
[1,1]=depthwise-separable(3).  For transformer supernets the same four
slots mean identity / full / bottleneck / lite (DESIGN.md Section 3).

Keys travel as int arrays (one int in [0,4) per block); the binary string
form used by the genetic operators is 2*L bits.
"""
from __future__ import annotations

import numpy as np

NUM_BRANCHES = 4
BITS_PER_BLOCK = 2


def random_key(rng: np.random.Generator, num_blocks: int) -> np.ndarray:
    return rng.integers(0, NUM_BRANCHES, size=num_blocks).astype(np.int32)


def key_to_bits(key: np.ndarray) -> np.ndarray:
    """(L,) ints in [0,4) -> (2L,) bits, MSB first per block."""
    key = np.asarray(key, dtype=np.int64)
    hi = (key >> 1) & 1
    lo = key & 1
    return np.stack([hi, lo], axis=1).reshape(-1).astype(np.int8)


def bits_to_key(bits: np.ndarray) -> np.ndarray:
    bits = np.asarray(bits, dtype=np.int64).reshape(-1, BITS_PER_BLOCK)
    return (bits[:, 0] * 2 + bits[:, 1]).astype(np.int32)


def one_point_crossover(rng: np.random.Generator, a_bits, b_bits):
    """Binary one-point crossover (paper Table I: p_c = 0.9)."""
    n = len(a_bits)
    point = int(rng.integers(1, n))
    c1 = np.concatenate([a_bits[:point], b_bits[point:]])
    c2 = np.concatenate([b_bits[:point], a_bits[point:]])
    return c1, c2


def bit_flip_mutation(rng: np.random.Generator, bits, p: float):
    """Binary bit-flip mutation (paper Table I: p_m = 0.1)."""
    flips = rng.random(len(bits)) < p
    out = np.asarray(bits).copy()
    out[flips] ^= 1
    return out


def make_offspring(rng: np.random.Generator, parent_keys, n_offspring: int,
                   p_crossover: float = 0.9, p_mutation: float = 0.1):
    """Generate offspring choice keys from parent keys (Algorithm 4 l.10-12)."""
    parents = list(parent_keys)
    out = []
    while len(out) < n_offspring:
        i, j = rng.choice(len(parents), size=2, replace=False)
        a, b = key_to_bits(parents[i]), key_to_bits(parents[j])
        if rng.random() < p_crossover:
            a, b = one_point_crossover(rng, a, b)
        a = bit_flip_mutation(rng, a, p_mutation)
        b = bit_flip_mutation(rng, b, p_mutation)
        out.extend([bits_to_key(a), bits_to_key(b)])
    return out[:n_offspring]
