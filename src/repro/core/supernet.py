"""Uniform supernet API over the two master-model families.

The paper's runtime (rt_enas / offline_enas) is model-agnostic: it needs
init / loss / error-rate / trained-mask / flops / payload as functions of a
choice key.  ``cnn_supernet_api`` is the paper-faithful CIFAR master model;
``lm_supernet_api`` is the transformer adaptation used with the assigned
architectures (DESIGN.md Section 3).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import aggregate, flops
from repro.core.choice import BITS_PER_BLOCK
from repro.models import cnn
from repro.models import transformer as tr
from repro.models.layers import cross_entropy

Params = Any


def choice_key_bytes(num_blocks: int) -> int:
    """Wire size of one choice key: 2 bits per choice block, byte-padded."""
    return (num_blocks * BITS_PER_BLOCK + 7) // 8


@dataclasses.dataclass(frozen=True)
class SupernetAPI:
    cfg: ModelConfig
    num_blocks: int
    init: Callable[[jax.Array], Params]
    loss: Callable[[Params, Dict, jax.Array], jax.Array]
    error_count: Callable[[Params, Dict, jax.Array], jax.Array]
    trained_mask: Callable[[Params, np.ndarray], Params]
    flops: Callable[[np.ndarray], float]
    payload_params: Callable[[np.ndarray], int]
    master_params: Callable[[], int]
    key_bytes: int = 0    # wire size of one choice key (2 bits per block)


def cnn_supernet_api(cfg: ModelConfig) -> SupernetAPI:
    assert cfg.family == "cnn"

    def init(rng):
        return cnn.init_params(rng, cfg)

    def loss(params, batch, key):
        logits = cnn.forward(params, batch["x"], key)
        onehot = jax.nn.one_hot(batch["y"], logits.shape[-1])
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        return -jnp.mean(jnp.sum(onehot * logp, axis=-1))

    def error_count(params, batch, key):
        logits = cnn.forward(params, batch["x"], key)
        return jnp.sum(jnp.argmax(logits, -1) != batch["y"])

    # per-(block, branch) parameter sizes, computed once
    _dummy = init(jax.random.PRNGKey(0))
    _total = sum(x.size for x in jax.tree.leaves(_dummy))
    _branch_sizes = [
        {nm: sum(x.size for x in jax.tree.leaves(blk[nm])) for nm in blk}
        for blk in _dummy["blocks"]
    ]
    _base = _total - sum(sum(b.values()) for b in _branch_sizes)

    def _master_params():
        return _total

    def payload(key):
        # shared stem/fc + only the selected branch of every choice block
        from repro.models.cnn import BRANCH_NAMES
        return _base + sum(_branch_sizes[i][BRANCH_NAMES[int(b)]]
                           for i, b in enumerate(np.asarray(key)))

    return SupernetAPI(
        cfg=cfg, num_blocks=cfg.num_layers, init=init, loss=loss,
        error_count=error_count,
        trained_mask=aggregate.cnn_trained_mask,
        flops=lambda key: float(flops.cnn_subnet_macs(key, cfg.num_layers)),
        payload_params=payload, master_params=_master_params,
        key_bytes=choice_key_bytes(cfg.num_layers))


def lm_supernet_api(cfg: ModelConfig) -> SupernetAPI:
    assert cfg.supernet and cfg.family in ("dense", "moe", "ssm")

    def init(rng):
        return tr.init_params(rng, cfg)

    def loss(params, batch, key):
        logits, aux, _ = tr.forward(params, cfg, batch["x"], choice_key=key)
        return cross_entropy(logits, batch["y"]) + 0.01 * aux

    def error_count(params, batch, key):
        logits, _, _ = tr.forward(params, cfg, batch["x"], choice_key=key)
        return jnp.sum(jnp.argmax(logits, -1) != batch["y"])

    def _master_params():
        return (flops.model_params(cfg)
                + 2 * cfg.num_layers * flops.layer_params(cfg))  # 3 branches

    def subnet_flops(key):
        # per-token fwd flops of the selected subnet (2 * params used)
        return 2.0 * flops.subnet_params(cfg, key)

    return SupernetAPI(
        cfg=cfg, num_blocks=cfg.num_layers, init=init, loss=loss,
        error_count=error_count,
        trained_mask=aggregate.supernet_trained_mask,
        flops=subnet_flops,
        payload_params=lambda key: flops.subnet_params(cfg, key),
        master_params=_master_params,
        key_bytes=choice_key_bytes(cfg.num_layers))


def make_api(cfg: ModelConfig) -> SupernetAPI:
    return cnn_supernet_api(cfg) if cfg.family == "cnn" else lm_supernet_api(cfg)
