"""Fill-aggregation (paper Algorithm 3).

Clients upload sub-models; the server reconstructs full master models by
*filling* the branches a client did not train with the previous master's
weights, then weighted-averages the reconstructions:

    theta(t) = sum_k w_k * ( mask_k * theta_k + (1 - mask_k) * theta(t-1) )

``mask_k`` marks the leaves client k actually trained, derived from its
choice key.  Non-choice-block leaves (stem, embeddings, norms, heads) have
mask 1 — they are trained by every client and plain-FedAvg'd, exactly the
``theta_k^i not in choice blocks`` case of Algorithm 3.

The reduction touches m x |theta| bytes and is the server-side hot spot at
production scale; ``repro.kernels.ops.fill_aggregate`` is the Pallas TPU
version of the flat inner loop (this module is its oracle).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Any


# ---------------------------------------------------------------------------
# Trained-leaf masks per model family
# ---------------------------------------------------------------------------

def cnn_trained_mask(params: Params, key: np.ndarray) -> Params:
    """Mask tree for the CIFAR CNN supernet (cnn.init_params layout)."""
    from repro.models.cnn import BRANCH_NAMES

    def ones_like(t):
        return jax.tree.map(lambda x: jnp.ones((), x.dtype), t)

    mask = {"stem": jnp.ones(()), "fc": ones_like(params["fc"]), "blocks": []}
    for i, blk in enumerate(params["blocks"]):
        bm = {}
        for b, name in enumerate(BRANCH_NAMES):
            sel = jnp.asarray(key[i] == b, jnp.float32)
            bm[name] = jax.tree.map(lambda x: sel, blk[name])
        mask["blocks"].append(bm)
    return mask


def supernet_trained_mask(params: Params, key: np.ndarray) -> Params:
    """Mask tree for transformer supernets: layer leaves are (L, 3, ...);
    branch b of layer l is trained iff key[l] == b + 1 (0 = identity trains
    nothing).  Everything outside ``layers`` is trained by every client."""
    key = jnp.asarray(key, jnp.int32)

    def layer_mask(x):
        l, nb = x.shape[0], x.shape[1]
        sel = (key[:, None] - 1) == jnp.arange(nb)[None, :]
        return sel.astype(jnp.float32).reshape((l, nb) + (1,) * (x.ndim - 2))

    mask = {}
    for k, v in params.items():
        if k == "layers":
            mask[k] = jax.tree.map(layer_mask, v)
        else:
            mask[k] = jax.tree.map(lambda x: jnp.ones((), jnp.float32), v)
    return mask


# ---------------------------------------------------------------------------
# Algorithm 3
# ---------------------------------------------------------------------------

def _flat_f32(leaves) -> jnp.ndarray:
    """Flatten leaves into one (P,) float32 vector (kernel layout)."""
    return jnp.concatenate([x.reshape(-1).astype(jnp.float32)
                            for x in leaves])


def _flat_mask_f32(mask_leaves, leaves) -> jnp.ndarray:
    """Flatten mask leaves (scalar- or partially-broadcast) against their
    parameter leaves into one (P,) float32 vector."""
    return jnp.concatenate(
        [jnp.broadcast_to(m, x.shape).reshape(-1).astype(jnp.float32)
         for m, x in zip(mask_leaves, leaves)])


def _unflatten_like(flat, leaves_ref, treedef) -> Params:
    """Inverse of ``_flat_f32``: slice a (P,) vector back into the
    reference leaves' shapes and dtypes."""
    out, off = [], 0
    for x in leaves_ref:
        n = x.size
        out.append(flat[off: off + n].reshape(x.shape).astype(x.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def fill_aggregate(prev_master: Params,
                   uploads: Sequence[Tuple[Params, Params, float]],
                   backend: str = "xla") -> Params:
    """uploads: [(client_params, trained_mask, weight n_k/n)].  Weights are
    normalized here so partial participation stays a proper average."""
    total = float(sum(w for _, _, w in uploads))

    if backend == "pallas":
        from repro.kernels import ops as kops
        leaves_prev, treedef = jax.tree.flatten(prev_master)
        flat_prev = _flat_f32(leaves_prev)
        cl, mk = [], []
        for cp, cm, _ in uploads:
            lc = jax.tree.leaves(cp)
            cl.append(_flat_f32(lc))
            mk.append(_flat_mask_f32(jax.tree.leaves(cm), lc))
        ws = jnp.asarray([w / total for _, _, w in uploads], jnp.float32)
        flat = kops.fill_aggregate(jnp.stack(cl), jnp.stack(mk), ws, flat_prev)
        return _unflatten_like(flat, leaves_prev, treedef)

    clients = tuple(cp for cp, _, _ in uploads)
    masks = tuple(cm for _, cm, _ in uploads)
    weights = jnp.asarray([w / total for _, _, w in uploads], jnp.float32)
    return _combine_jit(prev_master, clients, masks, weights)


@jax.jit
def _combine_jit(prev_master, clients, masks, weights):
    def combine(prev, *cm_flat):
        n = len(cm_flat) // 2
        acc = jnp.zeros_like(prev, dtype=jnp.float32)
        for i in range(n):
            cp, m = cm_flat[i], cm_flat[n + i]
            m = jnp.broadcast_to(m, prev.shape).astype(jnp.float32)
            filled = (m * cp.astype(jnp.float32)
                      + (1 - m) * prev.astype(jnp.float32))
            acc = acc + weights[i] * filled
        return acc.astype(prev.dtype)

    return jax.tree.map(combine, prev_master, *clients, *masks)


def fill_aggregate_stacked(prev_master: Params,
                           chunks: Sequence[Tuple[Params, Any, np.ndarray]],
                           mask_fn: Callable,
                           backend: str = "xla",
                           total: Optional[float] = None) -> Params:
    """Batched Algorithm 3 for the vmap/mesh execution backends.

    ``chunks`` holds stacked uploads: each entry is ``(stacked_params,
    keys, weights)`` where every leaf of ``stacked_params`` carries a
    leading (P,) upload axis, ``keys`` is (P, num_blocks) int32 and
    ``weights`` is (P,).  Trained masks are derived inside the jitted body
    via ``vmap(mask_fn)``, so one dispatch per chunk replaces the
    per-upload Python loop of ``fill_aggregate`` (its oracle).

    ``backend="pallas"`` routes the reduction through the
    ``repro.kernels.fill_aggregate`` TPU kernel on the flattened
    parameter vector (the same route ``fill_aggregate`` takes); off-TPU
    the kernel body executes in interpret mode (``kernels.ops.INTERPRET``)
    so the selection is valid everywhere.  Weight normalization is global
    across chunks, so per-chunk partial sums compose exactly; callers
    whose chunk weights are ALREADY normalized pass ``total=1.0`` (the
    fused/mesh routes) — re-deriving it from the float sum would shift
    every weight by ~1 ulp, and that amplifies over generations of SGD.
    """
    if total is None:
        total = float(sum(float(np.sum(w)) for _, _, w in chunks))
    if backend == "pallas":
        return _fill_stacked_pallas(prev_master, chunks, mask_fn, total)
    acc = None
    for stacked, keys, w in chunks:
        wnorm = jnp.asarray(np.asarray(w, np.float32) / total)
        part = _fill_stacked_partial(prev_master, stacked,
                                     jnp.asarray(keys, jnp.int32), wnorm,
                                     mask_fn=mask_fn)
        acc = part if acc is None else jax.tree.map(jnp.add, acc, part)
    return jax.tree.map(lambda a, p: a.astype(p.dtype), acc, prev_master)


def _fill_stacked_pallas(prev_master: Params, chunks, mask_fn: Callable,
                         total: float) -> Params:
    """Kernel route of ``fill_aggregate_stacked``: flatten every chunk to
    the (m, P) client/mask matrices the Pallas kernel consumes and sum
    the per-chunk partials (weights are globally normalized, so the
    kernel's ``sum_k w_k * filled_k`` partials add up to Algorithm 3)."""
    from repro.kernels import ops as kops

    leaves_prev, treedef = jax.tree.flatten(prev_master)
    flat_prev = _flat_f32(leaves_prev)
    flat = None
    for i, (stacked, keys, w) in enumerate(chunks):
        wnorm = jnp.asarray(np.asarray(w, np.float32) / total)
        cl, mk = _flatten_chunk(stacked, jnp.asarray(keys, jnp.int32),
                                mask_fn=mask_fn)
        # flat_prev is dead after the last chunk, so its buffer can be
        # aliased into that call's output (kernel-level donation)
        part = kops.fill_aggregate(cl, mk, wnorm, flat_prev,
                                   donate_prev=(i == len(chunks) - 1))
        flat = part if flat is None else flat + part
    return _unflatten_like(flat, leaves_prev, treedef)


@functools.partial(jax.jit, static_argnames=("mask_fn",))
def _flatten_chunk(stacked, keys, mask_fn):
    """(stacked leaves (m, ...), keys (m, nb)) -> (m, P) client and mask
    matrices over the flattened parameter vector."""
    masks = jax.vmap(mask_fn)(stacked, keys)
    lc = jax.tree.leaves(stacked)
    lm = jax.tree.leaves(masks)
    m = lc[0].shape[0]
    cl = jnp.concatenate(
        [x.reshape(m, -1).astype(jnp.float32) for x in lc], axis=1)
    mk = jnp.concatenate(
        [jnp.broadcast_to(
            mm.reshape(mm.shape + (1,) * (x.ndim - mm.ndim)),
            x.shape).reshape(m, -1).astype(jnp.float32)
         for mm, x in zip(lm, lc)], axis=1)
    return cl, mk


def fill_partial(prev_master: Params, stacked: Params, masks: Params,
                 wnorm) -> Params:
    """The Algorithm 3 partial sum over one stack of uploads: per leaf,
    ``sum_k w_k * (mask_k * client_k + (1 - mask_k) * prev)`` in float32,
    where every ``stacked``/``masks`` leaf carries a leading (P,) upload
    axis and ``wnorm`` is the (P,) globally-normalized weight vector
    (0-weight rows — padding — contribute exactly nothing).

    This is THE reduction expression of the batched fill paths: the
    stacked aggregator below, the mesh backend's shard_map body and the
    fused-generation programs all call it, so their float32 reduction
    order matches expression for expression — the backend-parity
    guarantees rest on that."""

    def combine(prev, cp, m):
        m = m.astype(jnp.float32)
        m = m.reshape(m.shape + (1,) * (cp.ndim - m.ndim))
        filled = (m * cp.astype(jnp.float32)
                  + (1 - m) * prev.astype(jnp.float32)[None])
        w = wnorm.reshape((-1,) + (1,) * (cp.ndim - 1))
        return jnp.sum(w * filled, axis=0)

    return jax.tree.map(combine, prev_master, stacked, masks)


@functools.partial(jax.jit, static_argnames=("mask_fn",))
def _fill_stacked_partial(prev_master, stacked, keys, wnorm, mask_fn):
    masks = jax.vmap(mask_fn)(stacked, keys)
    return fill_partial(prev_master, stacked, masks, wnorm)


def fedavg(uploads: Sequence[Tuple[Params, float]]) -> Params:
    """Plain FedAvg (Algorithm 1 line 9) — the paper's baseline aggregator."""
    total = float(sum(w for _, w in uploads))
    weights = jnp.asarray([w / total for _, w in uploads], jnp.float32)
    return _fedavg_jit(tuple(p for p, _ in uploads), weights)


@jax.jit
def _fedavg_jit(clients, weights):
    def avg(*xs):
        acc = jnp.zeros_like(xs[0], dtype=jnp.float32)
        for i, x in enumerate(xs):
            acc = acc + weights[i] * x.astype(jnp.float32)
        return acc.astype(xs[0].dtype)

    return jax.tree.map(avg, *clients)
