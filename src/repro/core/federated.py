"""Federated client/server primitives (Algorithm 1 + the client side of
Algorithm 4): jit-compiled local SGD over pre-batched shards, weighted
evaluation, and plain FedAvg rounds for the fixed-model baseline.

The choice key is a *traced* int32 vector everywhere, so one compilation of
the client update / evaluator serves every sub-model in the population —
this is what makes the search real-time on the server.
"""
from __future__ import annotations

from typing import Any, Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import fedavg
from repro.core.supernet import SupernetAPI
from repro.data.pipeline import ClientDataset
from repro.optim import sgd_init, sgd_update

Params = Any


def client_update_fn(api: SupernetAPI, epochs: int = 1,
                     momentum: float = 0.5) -> Callable:
    """Un-jitted client update body: E epochs of minibatch SGD from the
    downloaded (weight-inherited) master, on the selected subnet
    (Algorithm 4 lines 57-68).  The vmap execution backend maps this over
    stacked (individual, client) pairs; ``make_client_update`` is the
    jitted single-pair form."""

    def update(params: Params, key: jax.Array, xb, yb, lr):
        vel = sgd_init(params)

        def one_batch(carry, batch):
            p, v = carry
            x, y = batch
            g = jax.grad(api.loss)(p, {"x": x, "y": y}, key)
            p, v = sgd_update(p, g, v, lr, momentum)
            return (p, v), None

        def one_epoch(carry, _):
            return jax.lax.scan(one_batch, carry, (xb, yb))[0], None

        (params, _), _ = jax.lax.scan(one_epoch, (params, vel), None,
                                      length=epochs)
        return params

    return update


def make_client_update(api: SupernetAPI, epochs: int = 1,
                       momentum: float = 0.5) -> Callable:
    """Jit-compiled client update (one (individual, client) pair per call)."""
    return jax.jit(client_update_fn(api, epochs, momentum))


def eval_count_fn(api: SupernetAPI) -> Callable:
    """Un-jitted error counter over a client's pre-batched test shard."""

    def evaluate(params: Params, key: jax.Array, xb, yb):
        def one(acc, batch):
            x, y = batch
            return acc + api.error_count(params, {"x": x, "y": y}, key), None
        errs, _ = jax.lax.scan(one, jnp.zeros((), jnp.int32), (xb, yb))
        return errs

    return evaluate


def make_evaluator(api: SupernetAPI) -> Callable:
    """Jit-compiled test-error counter (one (key, client) pair per call)."""
    return jax.jit(eval_count_fn(api))


def weighted_test_error(evaluate, params, key, clients: Sequence[ClientDataset]
                        ) -> float:
    """Paper Algorithm 4 line 49: weighted average of client test errors."""
    wrong = total = 0
    for c in clients:
        xb, yb = c.test
        wrong += int(evaluate(params, key, xb, yb))
        total += xb.shape[0] * xb.shape[1]
    return wrong / max(total, 1)


def fedavg_round(update, params: Params, key: jax.Array,
                 clients: Sequence[ClientDataset], lr) -> Params:
    """One FedAvg round of the fixed-model baseline (all clients train the
    same model; plain weighted averaging)."""
    uploads = []
    for c in clients:
        xb, yb = c.train
        p_k = update(params, key, xb, yb, lr)
        uploads.append((p_k, c.weight))
    return fedavg(uploads)
