"""Analytic FLOPs/MACs — the paper's second objective, plus the roofline
MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) terms.
"""
from __future__ import annotations

import numpy as np

from repro.configs import cifar_supernet as cs
from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# CNN supernet MACs per choice key (paper objective 2; MAC convention, as in
# Table IV where ResNet18 = 0.5587 GMAC on 32x32 CIFAR)
# ---------------------------------------------------------------------------

def _conv_macs(h, w, cin, cout, k, stride=1, groups=1):
    ho, wo = h // stride, w // stride
    return ho * wo * cout * cin // groups * k * k


def cnn_branch_macs(name: str, h: int, w: int, cin: int, cout: int) -> int:
    red = cout != cin
    stride = 2 if red else 1
    if name == "identity":
        if not red:
            return 0
        return 2 * _conv_macs(h, w, cin, cout // 2, 1, 2)
    if name == "residual":
        return (_conv_macs(h, w, cin, cout, 3, stride)
                + _conv_macs(h // stride, w // stride, cout, cout, 3))
    if name == "inverted":
        hid = 4 * cin
        return (_conv_macs(h, w, cin, hid, 1)
                + _conv_macs(h, w, hid, hid, 3, stride, groups=hid)
                + _conv_macs(h // stride, w // stride, hid, cout, 1))
    if name == "sepconv":
        ho, wo = h // stride, w // stride
        return (_conv_macs(h, w, cin, cin, 3, stride, groups=cin)
                + _conv_macs(ho, wo, cin, cout, 1)
                + _conv_macs(ho, wo, cout, cout, 3, groups=cout)
                + _conv_macs(ho, wo, cout, cout, 1))
    raise ValueError(name)


def cnn_subnet_macs(key: np.ndarray, num_blocks: int = 12,
                    image: int = cs.IMAGE_SIZE) -> int:
    from repro.models.cnn import BRANCH_NAMES
    chans = cs.channels_for(num_blocks)
    cin = cs.stem_channels_for(num_blocks)
    h = w = image
    total = _conv_macs(h, w, 3, cin, 3)
    for i in range(num_blocks):
        cout = chans[i]
        total += cnn_branch_macs(BRANCH_NAMES[int(key[i])], h, w, cin, cout)
        if cout != cin:
            h, w = h // 2, w // 2
        cin = cout
    total += cin * cs.NUM_CLASSES
    return int(total)


# ---------------------------------------------------------------------------
# Transformer parameter counts and per-token FLOPs
# ---------------------------------------------------------------------------

def attn_params(cfg: ModelConfig) -> int:
    d, hd = cfg.d_model, cfg.hd
    return d * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)


def mlp_params(cfg: ModelConfig, d_ff=None, gated=True) -> int:
    f = d_ff if d_ff is not None else cfg.d_ff
    return cfg.d_model * f * (3 if gated else 2)


def ssm_params(cfg: ModelConfig) -> int:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    return (d * (2 * di + 2 * n + h)          # in_proj
            + cfg.ssm_conv * (di + 2 * n)     # conv
            + 3 * h + di                      # A_log, dt_bias, D, norm
            + di * d)                         # out_proj


def layer_params(cfg: ModelConfig, branch: int = 1) -> int:
    """Parameter count of one layer for a given supernet branch
    (0=identity, 1=full, 2=bottleneck, 3=lite — counts only the weights the
    branch actually *uses*; the master stores all branches)."""
    fam = cfg.family
    if branch == 0:
        return 0
    if fam in ("dense", "vlm"):
        a, m = attn_params(cfg), mlp_params(cfg)
        if branch == 2:
            m //= 2
        if branch == 3:
            a -= cfg.d_model * cfg.hd * cfg.num_heads  # half q + half o
        return a + m + 2 * cfg.d_model
    if fam == "moe":
        f = cfg.moe_d_ff or cfg.d_ff
        a = attn_params(cfg)
        e = cfg.num_experts * cfg.d_model * f * 3 + cfg.d_model * cfg.num_experts
        if branch == 2:
            e //= 2
        if branch == 3:
            a -= cfg.d_model * cfg.hd * cfg.num_heads
        sh = mlp_params(cfg) if cfg.shared_expert else 0
        return a + e + sh + 2 * cfg.d_model
    if fam in ("ssm", "hybrid"):
        s = ssm_params(cfg)
        if branch in (2, 3):
            s = int(s * 0.75)   # masked half-state / half-heads
        return s + cfg.d_model
    if fam == "audio":
        return (attn_params(cfg) * 2 + mlp_params(cfg, gated=False)
                + 3 * cfg.d_model)
    raise ValueError(fam)


def model_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Total parameter count (active_only: count top_k experts only)."""
    n = cfg.vocab_size * cfg.d_model + cfg.d_model       # embed + final ln
    per_layer = layer_params(cfg)
    if cfg.family == "moe" and active_only:
        f = cfg.moe_d_ff or cfg.d_ff
        dense_experts = cfg.num_experts * cfg.d_model * f * 3
        active_experts = cfg.top_k * cfg.d_model * f * 3
        per_layer = per_layer - dense_experts + active_experts
    n += cfg.num_layers * per_layer
    if cfg.family == "hybrid":
        n += (attn_params(cfg) + mlp_params(cfg) + 2 * cfg.d_model)  # shared
    if cfg.family == "audio":
        enc = (attn_params(cfg) + mlp_params(cfg, gated=False)
               + 2 * cfg.d_model)
        n += cfg.encoder_layers * enc + cfg.d_model
    if cfg.family == "vlm":
        n += cfg.d_model * cfg.d_model + cfg.d_model     # projector
    return int(n)


def subnet_params(cfg: ModelConfig, key: np.ndarray) -> int:
    """Parameters of the sub-model selected by ``key`` (transferred payload)."""
    n = cfg.vocab_size * cfg.d_model + cfg.d_model
    for b in np.asarray(key).tolist():
        n += layer_params(cfg, int(b))
    if cfg.family == "hybrid":
        n += attn_params(cfg) + mlp_params(cfg) + 2 * cfg.d_model
    return int(n)


def train_flops(cfg: ModelConfig, tokens: int) -> float:
    """MODEL_FLOPS for the roofline: 6 * N_active * D."""
    return 6.0 * model_params(cfg, active_only=True) * tokens


def decode_flops(cfg: ModelConfig, batch: int) -> float:
    """Per decode step: 2 * N_active * batch (fwd only)."""
    return 2.0 * model_params(cfg, active_only=True) * batch
