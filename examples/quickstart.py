"""Quickstart: 60 seconds with the RT-FedENAS framework.

1. build the paper's CNN supernet master model,
2. sample sub-networks with choice keys and inspect their FLOPs,
3. run TWO generations of real-time federated evolutionary NAS
   (double-sampling + fill-aggregation + NSGA-II) on synthetic clients
   through the FedEngine's vectorized ("vmap") execution backend,
4. print the Pareto front.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import make_api, nsga2
from repro.core.choice import random_key
from repro.data import make_classification, make_clients, partition_iid
from repro.engine import FedEngine, RealTimeNas, RunConfig


def main():
    # --- the master model (paper Fig. 3, CPU-reduced) -------------------
    cfg = get_config("cifar-supernet", smoke=True)
    api = make_api(cfg)
    print(f"master model: {cfg.name}, {cfg.num_layers} choice blocks, "
          f"{api.master_params() / 1e6:.2f}M params")

    rng = np.random.default_rng(0)
    for _ in range(3):
        key = random_key(rng, api.num_blocks)
        print(f"  choice key {key} -> {api.flops(key) / 1e6:7.1f} MMACs, "
              f"payload {api.payload_params(key) / 1e6:.2f}M params")

    # --- synthetic federated clients ------------------------------------
    x, y = make_classification(0, 1200, image=16)
    clients = make_clients(x, y, partition_iid(0, len(x), 8),
                           batch=50, test_batch=50)
    print(f"{len(clients)} clients, ~{clients[0].n_train} train samples each")

    # --- two generations of real-time evolutionary NAS ------------------
    engine = FedEngine(api, clients,
                       RunConfig(population=4, generations=2, seed=0,
                                 backend="vmap"),
                       strategy=RealTimeNas())
    hist = engine.run().history()
    objs = hist["objs"][-1]
    front = nsga2.fast_non_dominated_sort(objs)[0]
    print("\nPareto front after 2 generations (err, MMACs):")
    for i in sorted(front, key=lambda i: objs[i, 1]):
        print(f"  err={objs[i, 0]:.3f}  flops={objs[i, 1] / 1e6:8.1f}M")
    print(f"\ncomm so far: down {hist['down_gb'][-1]:.3f} GB, "
          f"up {hist['up_gb'][-1]:.3f} GB, "
          f"client passes {hist['train_passes'][-1]}, "
          f"jitted dispatches {engine.backend.dispatches}")


if __name__ == "__main__":
    main()
