"""Serve a reduced model with batched requests: prefill + greedy decode
through the same decode_step the decode_32k / long_500k dry-run shapes
lower.  Includes a sliding-window decode demo (the long_500k mechanism).

Run:  PYTHONPATH=src python examples/serve_batched.py --arch qwen1.5-0.5b
      PYTHONPATH=src python examples/serve_batched.py --arch mamba2-780m
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.serve import greedy_generate
from repro.models import transformer as tr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--window", type=int, default=0,
                    help=">0: sliding-window ring-buffer decode")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    rng = jax.random.PRNGKey(0)
    params = tr.init_params(rng, cfg)
    prompt = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size, dtype=jnp.int32)
    prefix = None
    if cfg.family in ("vlm", "audio"):
        prefix = jnp.zeros((args.batch, cfg.num_prefix, cfg.d_model),
                           jnp.float32)

    cache_len = (min(args.window, args.prompt_len + args.steps)
                 if args.window else args.prompt_len + args.steps)
    t0 = time.time()
    toks = greedy_generate(params, cfg, prompt, args.steps,
                           cache_len=cache_len, window=args.window,
                           prefix=prefix)
    dt = time.time() - t0
    n_new = args.batch * args.steps
    print(f"{cfg.name}: {args.batch} requests x {args.steps} new tokens "
          f"in {dt:.1f}s ({n_new / dt:.1f} tok/s, "
          f"cache_len={cache_len}{', sliding' if args.window else ''})")
    print("first request:", toks[0].tolist())


if __name__ == "__main__":
    main()
