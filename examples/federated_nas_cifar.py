"""End-to-end driver: the paper's experiment (Section IV) at CPU scale.

Real-time federated evolutionary NAS on the CNN supernet over IID or
non-IID synthetic clients, against BOTH baselines the paper uses:
  * FedAvg on a fixed all-residual model (the ResNet18 role, Table IV),
  * offline evolutionary NAS (reinit + every client trains every
    individual, Section IV.G).

Writes history JSON next to benchmarks/results for EXPERIMENTS.md.

Run (quick):  PYTHONPATH=src python examples/federated_nas_cifar.py \
                  --generations 5 --clients 8
Full paper-shaped run: --generations 40 --clients 10 (takes ~1 h on CPU).
"""
import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks import fed_nas
from repro.core import nsga2


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--generations", type=int, default=5)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--population", type=int, default=6)
    ap.add_argument("--noniid", action="store_true")
    ap.add_argument("--offline-generations", type=int, default=2)
    ap.add_argument("--baseline-rounds", type=int, default=0,
                    help="0 = same as --generations")
    ap.add_argument("--engine-backend", default="loop",
                    choices=["loop", "vmap", "mesh"],
                    help="client-execution backend (FedEngine); for "
                         "'mesh' on a CPU host set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8 "
                         "before launch to get devices to shard over")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="benchmarks/results")
    args = ap.parse_args()

    api = fed_nas.build_api()
    clients = fed_nas.build_clients(args.clients, iid=not args.noniid,
                                    seed=args.seed)
    tag = ("noniid" if args.noniid else "iid") + f"_c{args.clients}"

    print(f"=== RT-FedENAS ({tag}): {args.generations} generations, "
          f"pop {args.population} ===")
    t0 = time.time()
    hist = fed_nas.run_rt(api, clients, args.generations,
                          population=args.population, seed=args.seed,
                          engine_backend=args.engine_backend)
    rt_wall = time.time() - t0
    front = fed_nas.summarize_front(api, hist)
    print(f"  wall {rt_wall:.0f}s | best err "
          f"{hist['best_err'][0]:.3f} -> {hist['best_err'][-1]:.3f}")
    for r in front:
        print(f"  front: err={r['err']:.3f} flops={r['flops']/1e6:.1f}M")

    print("=== FedAvg fixed baseline (ResNet role) ===")
    rounds = args.baseline_rounds or args.generations
    base = fed_nas.run_fixed_baseline(api, clients, rounds, seed=args.seed,
                                      engine_backend=args.engine_backend)
    print(f"  err {base['err'][0]:.3f} -> {base['err'][-1]:.3f} "
          f"@ {base['flops']/1e6:.1f} MMACs")

    print(f"=== offline ENAS baseline: {args.offline_generations} gens ===")
    t0 = time.time()
    off = fed_nas.run_offline(api, clients, args.offline_generations,
                              population=args.population, seed=args.seed,
                              engine_backend=args.engine_backend)
    off_wall = time.time() - t0
    per_gen_rt = rt_wall / args.generations
    per_gen_off = off_wall / args.offline_generations
    print(f"  per-generation wall: RT {per_gen_rt:.1f}s vs offline "
          f"{per_gen_off:.1f}s -> RT is {per_gen_off/per_gen_rt:.1f}x "
          f"faster (paper: ~5x)")
    print(f"  upload volume: RT {hist['up_gb'][-1]:.3f} GB "
          f"({args.generations} gens) vs offline {off['up_gb'][-1]:.3f} GB "
          f"({args.offline_generations} gens)")

    os.makedirs(args.out, exist_ok=True)
    fed_nas.save_history(
        os.path.join(args.out, f"fednas_rt_{tag}.json"), hist,
        extra={"front": front, "rt_wall_s": rt_wall,
               "baseline_err": base["err"],
               "baseline_flops": base["flops"],
               "offline_per_gen_s": per_gen_off,
               "rt_per_gen_s": per_gen_rt,
               "offline_up_gb": off["up_gb"][-1],
               "offline_gens": args.offline_generations,
               "offline_best_err": off["best_err"]})
    print(f"history saved to {args.out}/fednas_rt_{tag}.json")


if __name__ == "__main__":
    main()
