"""Train a reduced assigned-architecture LM on a synthetic Markov stream —
exercises the same make_train_step the production launcher lowers, on the
host mesh, with loss-goes-down validation.

Run:  PYTHONPATH=src python examples/train_lm.py --arch qwen1.5-0.5b
      PYTHONPATH=src python examples/train_lm.py --arch mamba2-780m
Also demonstrates the paper technique on a transformer: --supernet samples
a random choice key per step (one-shot supernet training).
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import make_lm_stream
from repro.launch.train import init_opt, make_train_step
from repro.models import transformer as tr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--supernet", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    if args.supernet:
        cfg = cfg.replace(supernet=True)
    rng = jax.random.PRNGKey(0)
    params = tr.init_params(rng, cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name} (smoke): {n_params/1e6:.2f}M params"
          f"{' [supernet]' if args.supernet else ''}")

    opt = init_opt(params, "adamw")
    step_fn = jax.jit(make_train_step(cfg, optimizer="adamw", lr=args.lr,
                                      remat=False))
    x, y = make_lm_stream(0, args.steps * args.batch, args.seq,
                          cfg.vocab_size)
    key_rng = np.random.default_rng(0)
    first = last = None
    for i in range(args.steps):
        batch = {"tokens": x[i*args.batch:(i+1)*args.batch],
                 "labels": y[i*args.batch:(i+1)*args.batch]}
        if cfg.family in ("vlm", "audio"):
            batch["prefix"] = np.zeros(
                (args.batch, cfg.num_prefix, cfg.d_model), np.float32)
        if args.supernet:
            batch["choice_key"] = jnp.asarray(
                key_rng.integers(0, 4, cfg.num_layers), jnp.int32)
        params, opt, loss = step_fn(params, opt, batch)
        if first is None:
            first = float(loss)
        last = float(loss)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"  step {i:4d}  loss {float(loss):.4f}")
    assert last < first, "loss did not decrease"
    print(f"loss {first:.3f} -> {last:.3f}  (decreased: OK)")


if __name__ == "__main__":
    main()
