"""Client-availability simulation (``ClientSimConfig``): survivor-mask
invariants, backend parity under dropout, graceful group degeneration,
the wasted-bytes ledger, and the no-op guarantee (an inactive — or
active but harmless — simulation reproduces the synchronous trajectories
bit for bit)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import make_api
from repro.core.double_sampling import sample_client_groups
from repro.data import make_classification, make_clients, partition_iid
from repro.engine import ClientSimConfig, ClientSimulator, FedEngine, \
    OfflineNas, RunConfig
from repro.engine.availability import RoundSim

PARITY_BACKENDS = ("loop", "vmap", "mesh")


def tiny_clients(num_clients=8, n=480, seed=0):
    x, y = make_classification(seed, n, image=8, signal=1.5, noise=0.5)
    return make_clients(x, y, partition_iid(seed, n, num_clients),
                        batch=20, test_batch=20)


@pytest.fixture(scope="module")
def api():
    return make_api(get_config("cifar-supernet", smoke=True))


def leaves_equal(a, b):
    return all(np.array_equal(np.asarray(p), np.asarray(q))
               for p, q in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def max_leaf_diff(a, b):
    return max(float(np.abs(np.asarray(p) - np.asarray(q)).max())
               for p, q in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# config validation / simulator unit behavior
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    {"availability": 0.0}, {"availability": 1.5}, {"dropout": -0.1},
    {"dropout": 1.01}, {"straggler_fraction": 2.0},
    {"straggler_slowdown": 0.5}, {"round_deadline": 0.0},
    {"availability_trace": (0.5, 2.0)},
    # stragglers without a deadline would silently simulate nothing
    {"straggler_fraction": 0.3, "straggler_slowdown": 10.0},
])
def test_client_sim_config_rejected_at_config_time(kw):
    with pytest.raises(ValueError):
        ClientSimConfig(**kw)


def test_run_config_accepts_client_sim_dict():
    cfg = RunConfig(client_sim={"dropout": 0.25})
    assert isinstance(cfg.client_sim, ClientSimConfig)
    assert cfg.client_sim.dropout == 0.25
    assert cfg.client_sim.is_active
    assert not RunConfig().client_sim.is_active


def test_trace_length_validated_at_engine_build(api):
    clients = tiny_clients(num_clients=4, n=240)
    with pytest.raises(ValueError, match="availability_trace"):
        FedEngine(api, clients, RunConfig(
            client_sim=ClientSimConfig(availability_trace=(0.5, 0.5))))


def test_simulator_is_deterministic_and_separate_stream():
    sampled = np.arange(10)
    draws = []
    for _ in range(2):
        sim = ClientSimulator(ClientSimConfig(dropout=0.4, seed=3), 10)
        ctx = sim.draw_round(sampled)
        draws.append((tuple(ctx.participants), tuple(sorted(ctx.survivors)),
                      tuple(ctx.dropped)))
    assert draws[0] == draws[1]
    sim = ClientSimulator(ClientSimConfig(), 10)
    ctx = sim.draw_round(sampled)
    assert ctx.survivors is None and ctx.n_dropped == 0
    np.testing.assert_array_equal(ctx.participants, sampled)


def test_stragglers_always_miss_a_tight_deadline():
    """slowdown 10 vs deadline 2: every straggler's finish time
    (10 x U(0.8, 1.2)) exceeds the deadline; normal clients never do."""
    cfg = ClientSimConfig(straggler_fraction=0.5, straggler_slowdown=10.0,
                          round_deadline=2.0)
    sim = ClientSimulator(cfg, 10)
    slow = {i for i in range(10) if sim.speed[i] > 1.0}
    assert len(slow) == 5
    for _ in range(20):
        ctx = sim.draw_round(np.arange(10))
        assert set(int(c) for c in ctx.dropped) == slow


def test_availability_filter_preserves_order():
    sim = ClientSimulator(ClientSimConfig(availability=0.5, seed=0), 16)
    sampled = np.random.default_rng(1).permutation(16)
    ctx = sim.draw_round(sampled)
    pos = {int(c): i for i, c in enumerate(sampled)}
    order = [pos[int(c)] for c in ctx.participants]
    assert order == sorted(order)       # subsequence of the sampled order


# ---------------------------------------------------------------------------
# participation policy: graceful group degeneration
# ---------------------------------------------------------------------------

def test_groups_unchanged_when_enough_clients():
    """m >= N keeps the exact legacy semantics (groups of floor(m/N),
    extras idle) — same RNG stream, same arrays."""
    participants = np.arange(11)
    a = sample_client_groups(np.random.default_rng(7), participants, 4)
    rng = np.random.default_rng(7)
    perm = rng.permutation(participants)
    assert [g.tolist() for g in a] == [perm[i * 2:(i + 1) * 2].tolist()
                                       for i in range(4)]


def test_groups_degrade_gracefully_below_population():
    groups = sample_client_groups(np.random.default_rng(0), np.arange(3), 5)
    assert [len(g) for g in groups] == [1, 1, 1, 0, 0]
    assert sorted(int(g[0]) for g in groups[:3]) == [0, 1, 2]
    empty = sample_client_groups(np.random.default_rng(0),
                                 np.empty(0, np.int64), 4)
    assert [len(g) for g in empty] == [0, 0, 0, 0]


def test_strict_groups_still_reject_short_fleets(api):
    """Degeneration is an availability feature, not a license to
    misconfigure: a fully synchronous run (no ClientSimConfig) with
    population > clients still fails loudly, like it always did."""
    with pytest.raises(ValueError, match="need >= 5 clients"):
        sample_client_groups(np.random.default_rng(0), np.arange(3), 5,
                             strict=True)
    clients = tiny_clients(num_clients=3, n=180)
    eng = FedEngine(api, clients,
                    RunConfig(population=5, generations=1, seed=0))
    with pytest.raises(ValueError, match="need >= 5 clients"):
        eng.run()
    # the same fleet under an active availability sim runs fine
    res = FedEngine(api, clients,
                    RunConfig(population=5, generations=1, seed=0,
                              client_sim=ClientSimConfig(dropout=0.2))).run()
    assert np.isfinite(res.reports[0].objs).all()


# ---------------------------------------------------------------------------
# the no-op guarantee: dropout=0 => bitwise-identical to the legacy path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bk", ["loop", "vmap"])
def test_harmless_sim_bitwise_identical_to_default(api, bk):
    """An ACTIVE simulation that never drops anyone (generous deadline,
    no dropout) must reproduce the default path bit for bit: master,
    CommStats and objs — the sim draws from its own RNG stream, so the
    search is untouched."""
    clients = tiny_clients()
    runs = {}
    for name, sim in (("off", None),
                      ("noop", ClientSimConfig(round_deadline=100.0))):
        cfg = RunConfig(population=4, generations=2, seed=0, lr0=0.01,
                        backend=bk,
                        **({} if sim is None else {"client_sim": sim}))
        runs[name] = FedEngine(api, clients, cfg).run()
    assert leaves_equal(runs["off"].extras["final_master"],
                        runs["noop"].extras["final_master"])
    assert dataclasses.asdict(runs["off"].stats) == \
        dataclasses.asdict(runs["noop"].stats)
    for a, b in zip(runs["off"].reports, runs["noop"].reports):
        np.testing.assert_array_equal(a.objs, b.objs)
    # the harmless sim still reports availability (all survive)...
    assert all(r.n_dropped == 0 and r.n_survivors == len(clients)
               for r in runs["noop"].reports)
    assert runs["noop"].stats.wasted_down_bytes == 0.0
    # ...while the inactive run keeps the legacy history layout
    assert "n_survivors" not in runs["off"].history()
    assert all(r.n_survivors is None for r in runs["off"].reports)


# ---------------------------------------------------------------------------
# dropout: backend parity, survivor masking, the wasted ledger
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dropout_parity(api):
    clients = tiny_clients()
    sim = ClientSimConfig(dropout=0.3, seed=1)
    out = {}
    for bk in PARITY_BACKENDS:
        eng = FedEngine(api, clients,
                        RunConfig(population=4, generations=2, seed=0,
                                  lr0=0.01, backend=bk, client_sim=sim))
        out[bk] = (eng.run(), eng.backend.dispatches)
    return out


@pytest.mark.parametrize("bk", ["vmap", "mesh"])
def test_dropout_backend_parity(dropout_parity, bk):
    """30% dropout: all three backends agree — byte-identical CommStats
    (including the wasted ledger), objs within 1e-5, masters within
    1e-5."""
    loop, other = dropout_parity["loop"][0], dropout_parity[bk][0]
    assert dataclasses.asdict(loop.stats) == dataclasses.asdict(other.stats)
    assert loop.stats.wasted_down_bytes > 0
    for a, b in zip(loop.reports, other.reports):
        np.testing.assert_allclose(a.objs, b.objs, atol=1e-5)
        assert (a.n_dropped, a.n_survivors) == (b.n_dropped, b.n_survivors)
    assert max_leaf_diff(loop.extras["final_master"],
                         other.extras["final_master"]) <= 1e-5


@pytest.mark.parametrize("bk", ["vmap", "mesh"])
def test_dropout_keeps_fused_dispatch_bound(dropout_parity, bk):
    """Survivor masking rides weight-0 rows / int32 masks, so the fused
    path still issues exactly 2*gens + 1 dispatches under dropout."""
    assert dropout_parity[bk][1] == 2 * 2 + 1


def test_dropout_fused_vs_nonfused_parity(api):
    clients = tiny_clients()
    sim = ClientSimConfig(dropout=0.3, seed=1)
    out = {}
    for fused in (False, True):
        out[fused] = FedEngine(
            api, clients,
            RunConfig(population=4, generations=2, seed=0, lr0=0.01,
                      backend="vmap", fused=fused, client_sim=sim)).run()
    assert dataclasses.asdict(out[False].stats) == \
        dataclasses.asdict(out[True].stats)
    for a, b in zip(out[False].reports, out[True].reports):
        np.testing.assert_array_equal(a.objs, b.objs)
    assert max_leaf_diff(out[False].extras["final_master"],
                         out[True].extras["final_master"]) <= 1e-6


def test_full_dropout_freezes_master_and_uploads_nothing(api):
    """dropout=1.0: dropped clients never contribute — the master stays
    bitwise at its init, zero upload bytes, and every download is
    wasted."""
    clients = tiny_clients(num_clients=4, n=240)
    res = FedEngine(api, clients,
                    RunConfig(population=2, generations=2, seed=0,
                              lr0=0.01, backend="vmap",
                              client_sim=ClientSimConfig(dropout=1.0))).run()
    assert leaves_equal(res.extras["final_master"],
                        api.init(jax.random.PRNGKey(0)))
    assert res.stats.up_bytes == 0 and res.stats.up_wire_bytes == 0
    assert res.stats.eval_up_bytes == 0
    assert res.stats.wasted_down_bytes == res.stats.down_bytes > 0
    # no fitness reports: pessimistic error 1.0 everywhere
    assert all(float(e) == 1.0 for r in res.reports for e in r.objs[:, 0])


def test_wasted_ledger_arithmetic():
    from repro.engine import CommStats
    s = CommStats()
    s.add_download(100, copies=4, wire_bytes=100.0, wasted_copies=1)
    assert s.down_bytes == 1600 and s.down_wire_bytes == 400
    assert s.wasted_down_bytes == 400 and s.wasted_down_wire_bytes == 100
    s.add_eval_download_bytes(8, copies=3, wasted_copies=2)
    assert s.wasted_down_bytes == 416 and s.eval_down_bytes == 24


def test_dropped_only_in_uploads_not_downloads(api):
    """Per round: downloads go to every available participant (the
    dropped share booked as wasted), uploads only to survivors —
    checked against the per-round report counts."""
    clients = tiny_clients(num_clients=6, n=360)
    cfg = RunConfig(population=2, generations=3, seed=0, lr0=0.01,
                    backend="vmap",
                    client_sim=ClientSimConfig(dropout=0.4, seed=5))
    res = FedEngine(api, clients, cfg).run()
    from repro.engine import BYTES_PER_PARAM, ERROR_COUNT_BYTES
    two_n = 2 * cfg.population
    key_down = api.key_bytes * two_n
    master_down = BYTES_PER_PARAM * api.master_params()
    expect_eval_down = sum((master_down + key_down) * r.n_available
                           for r in res.reports)
    expect_eval_up = sum(ERROR_COUNT_BYTES * two_n * r.n_survivors
                         for r in res.reports)
    assert res.stats.eval_down_bytes == expect_eval_down
    assert res.stats.eval_up_bytes == expect_eval_up
    assert any(r.n_dropped > 0 for r in res.reports)


# ---------------------------------------------------------------------------
# availability / stragglers end to end
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_low_availability_degenerate_groups_run(api):
    """Availability far below population size: rounds run with partial
    (even empty) groups and the three backends still agree."""
    clients = tiny_clients(num_clients=6, n=360)
    sim = ClientSimConfig(availability=0.4, seed=2)
    out = {}
    for bk in PARITY_BACKENDS:
        out[bk] = FedEngine(api, clients,
                            RunConfig(population=5, generations=3, seed=0,
                                      lr0=0.01, backend=bk,
                                      client_sim=sim)).run()
    for bk in ("vmap", "mesh"):
        assert dataclasses.asdict(out["loop"].stats) == \
            dataclasses.asdict(out[bk].stats)
        for a, b in zip(out["loop"].reports, out[bk].reports):
            np.testing.assert_allclose(a.objs, b.objs, atol=1e-5)
    assert any(r.n_available < 5 for r in out["loop"].reports)
    assert all(np.isfinite(r.objs).all() for r in out["loop"].reports)


@pytest.mark.slow
def test_offline_strategy_under_dropout_parity(api):
    """The offline baseline's fedavg-population / eval-paired paths
    renormalize over survivors identically on every backend."""
    clients = tiny_clients(num_clients=4, n=240)
    sim = ClientSimConfig(dropout=0.5, seed=4)
    out = {}
    for bk in PARITY_BACKENDS:
        out[bk] = FedEngine(api, clients,
                            RunConfig(population=2, generations=1, seed=1,
                                      lr0=0.01, backend=bk, client_sim=sim),
                            strategy=OfflineNas()).run()
    for bk in ("vmap", "mesh"):
        assert dataclasses.asdict(out["loop"].stats) == \
            dataclasses.asdict(out[bk].stats)
        np.testing.assert_allclose(out["loop"].reports[0].objs,
                                   out[bk].reports[0].objs, atol=1e-5)


def test_straggler_deadline_wastes_bytes_every_round(api):
    """Deterministic stragglers (slowdown 10 vs deadline 2) miss every
    round: the wasted ledger grows monotonically round over round."""
    clients = tiny_clients(num_clients=6, n=360)
    sim = ClientSimConfig(straggler_fraction=0.34, straggler_slowdown=10.0,
                          round_deadline=2.0, seed=0)
    res = FedEngine(api, clients,
                    RunConfig(population=3, generations=3, seed=0,
                              lr0=0.01, backend="vmap",
                              client_sim=sim)).run()
    wasted = [r.wasted_down_gb for r in res.reports]
    assert all(r.n_dropped == 2 for r in res.reports)
    assert all(b > a for a, b in zip(wasted, wasted[1:]))


@pytest.mark.slow
def test_codec_times_dropout_backend_parity(api):
    """The full matrix claim: availability composes with the payload
    codecs — int8 uplink + 30% dropout still yields byte-identical
    CommStats (both ledgers + wasted) and close masters across
    backends."""
    clients = tiny_clients(num_clients=4, n=240)
    sim = ClientSimConfig(dropout=0.3, seed=2)
    out = {}
    for bk in ("loop", "vmap"):
        out[bk] = FedEngine(api, clients,
                            RunConfig(population=3, generations=2, seed=0,
                                      lr0=0.01, backend=bk,
                                      uplink_codec="int8",
                                      client_sim=sim)).run()
    assert dataclasses.asdict(out["loop"].stats) == \
        dataclasses.asdict(out["vmap"].stats)
    assert out["loop"].stats.up_wire_bytes < out["loop"].stats.up_bytes
    for a, b in zip(out["loop"].reports, out["vmap"].reports):
        np.testing.assert_allclose(a.objs, b.objs, atol=1e-5)
    # int8 quantization of the uplink delta amplifies the usual <=1e-5
    # loop-vs-vmap reduction-order noise slightly (the grid snaps near-
    # ties to different levels); errors above stay exact
    assert max_leaf_diff(out["loop"].extras["final_master"],
                         out["vmap"].extras["final_master"]) <= 5e-5


def test_run_is_reentrant_with_sim(api):
    """The simulator is rebuilt per run(): two runs of one engine
    produce identical survivor sequences and stats."""
    clients = tiny_clients(num_clients=4, n=240)
    eng = FedEngine(api, clients,
                    RunConfig(population=2, generations=2, seed=0,
                              lr0=0.01, backend="vmap",
                              client_sim=ClientSimConfig(dropout=0.4)))
    first, second = eng.run(), eng.run()
    assert dataclasses.asdict(first.stats) == dataclasses.asdict(second.stats)
    assert [r.n_survivors for r in first.reports] == \
        [r.n_survivors for r in second.reports]
    assert leaves_equal(first.extras["final_master"],
                        second.extras["final_master"])


@pytest.mark.slow
def test_25_generations_at_30pct_dropout(api):
    """The acceptance regression: a 25-generation run at 30% dropout
    completes, keeps the fused dispatch bound, reports survivors every
    round and ends with a finite search trajectory."""
    clients = tiny_clients()
    gens = 25
    eng = FedEngine(api, clients,
                    RunConfig(population=4, generations=gens, seed=0,
                              lr0=0.01, backend="vmap",
                              client_sim=ClientSimConfig(dropout=0.3,
                                                         seed=7)))
    res = eng.run()
    assert len(res.reports) == gens
    assert eng.backend.dispatches == 2 * gens + 1
    assert all(np.isfinite(r.objs).all() for r in res.reports)
    assert all(r.n_survivors + r.n_dropped == r.n_available
               for r in res.reports)
    assert sum(r.n_dropped for r in res.reports) > 0
    assert res.stats.wasted_down_bytes > 0
    hist = res.history()
    assert len(hist["n_survivors"]) == gens


def test_round_sim_inactive_shim():
    ctx = RoundSim.inactive(np.arange(3))
    assert not ctx.active and ctx.n_survivors == 3 and ctx.n_dropped == 0
