"""Fixed-seed fallback for ``hypothesis`` so the property-based tests
degrade to deterministic example-based tests when the real library is not
installed (it is declared in pyproject's test extra).

Implements just the surface this repo's tests use: ``given``, ``settings``
and the ``integers`` / ``floats`` / ``lists`` / ``tuples`` strategies with
``.map`` / ``.flatmap``.  Examples are drawn from one seeded generator, so
failures reproduce exactly; there is no shrinking.
"""
from __future__ import annotations

import types

import numpy as np

_FALLBACK_SEED = 20200303          # arXiv:2003.02793
_MAX_EXAMPLES_CAP = 25             # keep the degraded mode fast


class _Strategy:
    def __init__(self, draw):
        self.draw = draw

    def map(self, fn):
        return _Strategy(lambda rng: fn(self.draw(rng)))

    def flatmap(self, fn):
        return _Strategy(lambda rng: fn(self.draw(rng)).draw(rng))


def integers(min_value, max_value):
    return _Strategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value=0.0, max_value=1.0, allow_nan=False, **_):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def lists(elements, min_size=0, max_size=10):
    return _Strategy(lambda rng: [
        elements.draw(rng)
        for _ in range(int(rng.integers(min_size, max_size + 1)))])


def tuples(*elems):
    return _Strategy(lambda rng: tuple(e.draw(rng) for e in elems))


strategies = types.SimpleNamespace(integers=integers, floats=floats,
                                   lists=lists, tuples=tuples)


def settings(max_examples=20, deadline=None, **_):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strats):
    def deco(fn):
        def wrapper():
            n = min(getattr(wrapper, "_fallback_max_examples", 20),
                    _MAX_EXAMPLES_CAP)
            rng = np.random.default_rng(_FALLBACK_SEED)
            for _ in range(n):
                fn(*(s.draw(rng) for s in strats))
        # deliberately no functools.wraps: the wrapper must expose a
        # zero-arg signature or pytest treats the strategy-filled
        # parameters as fixtures
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
