"""Docs are executable: every fenced ```python block in docs/*.md must
run.  Blocks within one document share a namespace (later snippets may
use earlier imports), so each document is one test case.  Keep doc
snippets smoke-sized — this is the contract that keeps them honest."""
import pathlib
import re

import pytest

DOCS = sorted((pathlib.Path(__file__).parent.parent / "docs").glob("*.md"))

FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def python_blocks(path: pathlib.Path):
    return [m.group(1) for m in FENCE.finditer(path.read_text())]


def test_docs_exist_and_have_snippets():
    names = {p.name for p in DOCS}
    assert {"architecture.md", "kernels.md"} <= names
    assert all(python_blocks(p) for p in DOCS
               if p.name in ("architecture.md", "kernels.md"))


@pytest.mark.parametrize("doc", DOCS, ids=lambda p: p.name)
def test_docs_snippets_run(doc):
    blocks = python_blocks(doc)
    if not blocks:
        pytest.skip(f"{doc.name}: no python blocks")
    ns = {"__name__": f"docs_snippet_{doc.stem}"}
    for i, block in enumerate(blocks):
        try:
            exec(compile(block, f"{doc.name}[block {i}]", "exec"), ns)
        except Exception as e:   # pragma: no cover - failure reporting
            pytest.fail(f"{doc.name} block {i} failed: {e!r}\n{block}")
