"""Choice-key encoding + genetic-operator property tests."""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # degrade to fixed-seed examples
    from _hyp_fallback import given, settings, strategies as st

from repro.core import choice

keys = st.lists(st.integers(0, 3), min_size=1, max_size=24).map(
    lambda l: np.asarray(l, np.int32))


@settings(max_examples=100, deadline=None)
@given(keys)
def test_bits_roundtrip(key):
    bits = choice.key_to_bits(key)
    assert len(bits) == 2 * len(key)
    assert set(np.unique(bits)) <= {0, 1}
    np.testing.assert_array_equal(choice.bits_to_key(bits), key)


def test_paper_encoding_convention():
    # [0,0]=0 identity, [0,1]=1 residual, [1,0]=2 inverted, [1,1]=3 sepconv
    np.testing.assert_array_equal(
        choice.key_to_bits(np.array([0, 1, 2, 3])),
        np.array([0, 0, 0, 1, 1, 0, 1, 1]))


@settings(max_examples=50, deadline=None)
@given(keys, st.integers(0, 2**31 - 1))
def test_crossover_preserves_multiset(key, seed):
    rng = np.random.default_rng(seed)
    a, b = choice.key_to_bits(key), choice.key_to_bits(key[::-1].copy())
    c1, c2 = choice.one_point_crossover(rng, a, b)
    assert sorted(np.concatenate([c1, c2])) == sorted(np.concatenate([a, b]))


@settings(max_examples=50, deadline=None)
@given(keys, st.integers(0, 2**31 - 1))
def test_mutation_p0_and_p1(key, seed):
    rng = np.random.default_rng(seed)
    bits = choice.key_to_bits(key)
    np.testing.assert_array_equal(choice.bit_flip_mutation(rng, bits, 0.0),
                                  bits)
    np.testing.assert_array_equal(choice.bit_flip_mutation(rng, bits, 1.0),
                                  1 - bits)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(1, 12), st.integers(0, 2**31 - 1))
def test_make_offspring_count_and_validity(n_off, blocks, seed):
    rng = np.random.default_rng(seed)
    parents = [choice.random_key(rng, blocks) for _ in range(4)]
    off = choice.make_offspring(rng, parents, n_off)
    assert len(off) == n_off
    for k in off:
        assert len(k) == blocks and k.min() >= 0 and k.max() <= 3
