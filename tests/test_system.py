"""End-to-end behaviour of the paper's system (CPU-scaled).

Covers: double-sampling invariants, the real-time NAS loop (Algorithm 4),
the offline-ENAS baseline, the communication/compute accounting behind the
paper's efficiency claims, and the roofline HLO parser.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import make_api, nsga2, offline_enas, rt_enas
from repro.core.double_sampling import (
    sample_client_groups, sample_participants, sample_population_keys,
)
from repro.data import make_classification, make_clients, partition_iid, \
    partition_label


def tiny_clients(num_clients=8, n=480, image=8, seed=0, noniid=False):
    x, y = make_classification(seed, n, image=image, signal=1.5, noise=0.5)
    if noniid:
        shards = partition_label(seed, y, num_clients, classes_per_client=5)
    else:
        shards = partition_iid(seed, n, num_clients)
    return make_clients(x, y, shards, batch=20, test_batch=20)


@pytest.fixture(scope="module")
def api():
    return make_api(get_config("cifar-supernet", smoke=True))


# ---------------------------------------------------------------------------
# double-sampling
# ---------------------------------------------------------------------------

def test_client_groups_disjoint_without_replacement():
    rng = np.random.default_rng(0)
    participants = sample_participants(rng, 20, 1.0)
    groups = sample_client_groups(rng, participants, 6)
    assert len(groups) == 6
    flat = np.concatenate(groups)
    assert len(flat) == len(set(flat.tolist()))       # each client once
    assert all(len(g) == 20 // 6 for g in groups)     # L = floor(m/N)


def test_client_groups_degrade_below_population():
    """Fewer participants than individuals no longer fails the round
    (real-time availability): the first m groups get one client each,
    the rest stay empty and their blocks are filled from the master."""
    rng = np.random.default_rng(0)
    groups = sample_client_groups(rng, np.arange(3), 6)
    assert [len(g) for g in groups] == [1, 1, 1, 0, 0, 0]
    flat = np.concatenate([g for g in groups if len(g)])
    assert sorted(flat.tolist()) == [0, 1, 2]


def test_participation_fraction():
    rng = np.random.default_rng(1)
    assert len(sample_participants(rng, 20, 0.5)) == 10
    assert len(sample_participants(rng, 20, 1.0)) == 20


# ---------------------------------------------------------------------------
# real-time loop (Algorithm 4)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def rt_history(api):
    clients = tiny_clients()
    rc = rt_enas.RunConfig(population=4, generations=2, seed=0)
    return rt_enas.run(api, clients, rc), clients, rc


def test_rt_runs_and_reports(rt_history):
    hist, clients, rc = rt_history
    assert hist["gen"] == [1, 2]
    assert all(0.0 <= e <= 1.0 for e in hist["best_err"])
    objs = hist["objs"][-1]
    assert objs.shape == (2 * rc.population, 2)
    assert (objs[:, 1] > 0).all()                     # FLOPs objective


def test_rt_one_training_pass_per_client_per_generation(rt_history):
    """The paper's core efficiency claim: after generation 1 (which also
    trains parents), each generation adds exactly one pass per client."""
    hist, clients, rc = rt_history
    m = len(clients)
    assert hist["train_passes"][0] == 2 * m           # parents + offspring
    assert hist["train_passes"][1] - hist["train_passes"][0] == m


def test_rt_parent_selection_is_nsga2(rt_history):
    hist, _, rc = rt_history
    assert len(hist["parent_keys"][-1]) == rc.population
    # knee/best keys decode to valid branch ids
    assert set(np.asarray(hist["best_key"][-1]).tolist()) <= {0, 1, 2, 3}


# ---------------------------------------------------------------------------
# offline baseline + cost comparison (paper Section IV.G)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_offline_costs_dominate_rt(api):
    clients = tiny_clients()
    rc = rt_enas.RunConfig(population=4, generations=2, seed=0)
    hist_rt = rt_enas.run(api, clients, rc)
    hist_off = offline_enas.run(api, clients, rc)
    m, n = len(clients), rc.population
    # offline: every client trains every individual; parents evaluated once
    off_passes = hist_off["train_passes"][-1]
    rt_passes = hist_rt["train_passes"][-1]
    assert off_passes == (1 + 2) * n * m  # parents once + 2 gens offspring
    assert off_passes / rt_passes >= n / 2  # ~N x more local compute
    # upload volume is much larger offline
    assert hist_off["stats"].up_bytes > 2 * hist_rt["stats"].up_bytes


def test_offline_runs_and_reports(api):
    clients = tiny_clients()
    rc = rt_enas.RunConfig(population=3, generations=2, seed=1)
    hist_off = offline_enas.run(api, clients, rc)
    assert hist_off["gen"] == [1, 2]
    assert np.isfinite(hist_off["best_err"]).all()


# ---------------------------------------------------------------------------
# roofline HLO parser
# ---------------------------------------------------------------------------

def test_parse_collectives_counts_operands():
    from repro.launch.roofline import parse_collectives
    hlo = """
  %ar = bf16[128,256] all-reduce(bf16[128,256] %x), replica_groups={}
  %ag.1 = f32[64]{0} all-gather(f32[32]{0} %y), dimensions={0}
  %rs = f32[16] reduce-scatter(f32[64] %z), dimensions={0}
  %a2a.s = (f32[8,8]) all-to-all-start(f32[8,8] %w), dimensions={0}
  %a2a.d = f32[8,8] all-to-all-done(%a2a.s)
  %cp = u32[4] collective-permute(u32[4] %p), source_target_pairs={{0,1}}
  %not_a_collective = f32[2] add(f32[2] %a, f32[2] %b)
"""
    got = parse_collectives(hlo)
    assert got["all-reduce"] == 128 * 256 * 2
    assert got["all-gather"] == 64 * 4     # max(result, operand) side
    assert got["reduce-scatter"] == 64 * 4
    assert got["all-to-all"] == 8 * 8 * 4
    assert got["collective-permute"] == 4 * 4
    assert got["ops"] == 5
    assert got["total"] == sum(got[k] for k in
                               ("all-reduce", "all-gather", "reduce-scatter",
                                "all-to-all", "collective-permute"))


def test_roofline_terms_dominance():
    from repro.launch.roofline import roofline_terms
    t = roofline_terms(197e12, 0.0, 0.0)
    assert t["dominant"] == "compute" and t["compute_s"] == pytest.approx(1.0)
    t = roofline_terms(0.0, 819e9, 0.0)
    assert t["dominant"] == "memory" and t["memory_s"] == pytest.approx(1.0)
    t = roofline_terms(0.0, 0.0, 200e9)
    assert t["dominant"] == "collective"
    assert t["collective_s"] == pytest.approx(1.0)
