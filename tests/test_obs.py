"""repro.obs: telemetry is bit-exactly invisible when off, faithful
when on.

The contract under test, per backend x fused variant: enabling
``RunConfig.telemetry`` changes *nothing* about the search — masters
and per-generation objectives bitwise identical, CommStats equal field
for field, dispatch counts equal — while the enabled run emits one
complete ``RoundEvent`` per generation (phase spans with correct
nesting, recompile deltas, resource gauges, CommStats deltas).  Plus
the recompile counter honesty tests (traces counted, cached dispatches
not; the fused programs trace exactly once), the sink implementations,
and the shared gauge helpers the benchmark driver reuses.
"""
import dataclasses
import io
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import make_api
from repro.data import make_classification, make_clients, make_fleet, \
    partition_iid
from repro.engine import ClientSimConfig, FedEngine, RunConfig
from repro.obs import (COMM_FIELDS, NULL_TELEMETRY, InstrumentedBackend,
                       PeakLiveBytes, RoundEvent, TableSink, Telemetry,
                       TelemetryConfig, event_dict, host_rss_bytes,
                       innermost, live_device_bytes, parse_sink_spec,
                       steady_mean, traced)

VARIANTS = (("loop", True), ("vmap", True), ("vmap", False),
            ("mesh", True), ("mesh", False))
GENS = 3


def tiny_clients(num_clients=6, n=240, seed=0):
    x, y = make_classification(seed, n, image=8, signal=1.5, noise=0.5)
    return make_clients(x, y, partition_iid(seed, n, num_clients),
                        batch=10, test_batch=10)


@pytest.fixture(scope="module")
def api():
    return make_api(get_config("cifar-supernet", smoke=True))


def max_leaf_diff(a, b):
    return max(float(jnp.abs(jnp.asarray(x) - jnp.asarray(y)).max())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def run_engine(api, clients, backend, fused, telemetry, **kw):
    eng = FedEngine(api, clients,
                    RunConfig(population=4, generations=GENS, seed=0,
                              lr0=0.01, backend=backend, fused=fused,
                              telemetry=telemetry, **kw))
    return eng, eng.run()


@pytest.fixture(scope="module")
def onoff(api):
    clients = tiny_clients()
    return {(bk, fused): {t: run_engine(api, clients, bk, fused,
                                        True if t == "on" else None)
                          for t in ("off", "on")}
            for bk, fused in VARIANTS}


# ---------------------------------------------------------------------------
# bit-exact invisibility: on == off, per backend x fused variant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bk,fused", VARIANTS)
def test_telemetry_on_off_bitwise(onoff, bk, fused):
    (eng_off, off), (eng_on, on) = (onoff[(bk, fused)]["off"],
                                    onoff[(bk, fused)]["on"])
    assert max_leaf_diff(off.extras["final_master"],
                         on.extras["final_master"]) == 0.0
    for a, b in zip(off.reports, on.reports):
        assert np.array_equal(np.asarray(a.objs), np.asarray(b.objs))
        assert a.best_err == b.best_err
    assert dataclasses.asdict(off.stats) == dataclasses.asdict(on.stats)
    assert eng_off.backend.dispatches == eng_on.backend.dispatches


@pytest.mark.parametrize("bk,fused", VARIANTS)
def test_telemetry_result_presence(onoff, bk, fused):
    off = onoff[(bk, fused)]["off"][1]
    on = onoff[(bk, fused)]["on"][1]
    assert off.telemetry is None
    assert on.telemetry is not None
    assert [e.gen for e in on.telemetry.events] == list(range(1, GENS + 1))


def test_disabled_engine_is_pre_subsystem_graph(api):
    clients = tiny_clients(4, 120)
    rc = dict(population=4, generations=1, seed=0, backend="vmap")
    eng_off = FedEngine(api, clients, RunConfig(**rc))
    # no wrapper at all, and every telemetry hook is the shared no-op
    assert innermost(eng_off.backend) is eng_off.backend
    assert eng_off.telemetry is NULL_TELEMETRY
    assert eng_off.backend.telemetry is NULL_TELEMETRY
    eng_on = FedEngine(api, clients, RunConfig(telemetry=True, **rc))
    assert isinstance(eng_on.backend, InstrumentedBackend)
    assert innermost(eng_on.backend).telemetry is eng_on.telemetry


# ---------------------------------------------------------------------------
# round-event completeness (vmap fused + availability sim + int8 codec)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def full_run(api):
    return run_engine(api, tiny_clients(), "vmap", True, True,
                      uplink_codec="int8", downlink_codec="int8",
                      client_sim=ClientSimConfig(dropout=0.25, seed=1))


def test_round_event_spans_complete(full_run):
    _, res = full_run
    ev = res.telemetry.events[0]
    paths = set(ev.spans)
    for phase in ("sample", "availability", "fill_train", "eval",
                  "aggregate"):
        assert phase in paths, f"missing top-level span {phase!r}"
    # codec + staging spans nest under the backend call that caused them
    assert "fill_train/codec_decode" in paths
    assert "fill_train/codec_encode" in paths
    assert "eval/codec_decode" in paths
    assert any(p.endswith("/download") for p in paths)
    assert all(s >= 0.0 for s in ev.spans.values())
    assert ev.span_counts["eval"] >= 1
    assert set(ev.span_counts) == paths


def test_round_event_comm_deltas_sum_to_stats(full_run):
    _, res = full_run
    events = res.telemetry.events
    stats = dataclasses.asdict(res.stats)
    for f in COMM_FIELDS:
        per_round = [e.comm[f] for e in events]
        assert sum(per_round) == pytest.approx(stats[f])
    assert events[0].comm["down_bytes"] > 0
    assert events[0].comm["up_bytes"] > 0


def test_round_event_gauges(full_run):
    _, res = full_run
    g = res.telemetry.events[-1].gauges
    assert g["live_device_bytes"] > 0
    assert g["peak_live_device_bytes"] >= g["live_device_bytes"]
    assert g["host_rss_bytes"] > 0
    # stacked-store LRU counters (vmap backend): the steady state reuses
    # the staged shards, so by the last round there have been hits
    assert g["train_store_misses"] >= 1
    assert g["test_stack_misses"] >= 1
    assert g["train_store_hits"] + g["test_stack_hits"] >= 1


def test_round_event_times_match_reports(full_run):
    _, res = full_run
    for e, r in zip(res.telemetry.events, res.reports):
        assert e.round_s == r.round_s
        assert e.round_s >= 0.0
        # top-level phases are disjoint intervals inside the round
        top = sum(s for p, s in e.spans.items() if "/" not in p)
        assert top <= e.round_s + 1e-3


def test_fleet_gauges(api):
    x, y = make_classification(0, 120, image=8, signal=1.5, noise=0.5)
    fleet = make_fleet(x, y, partition_iid(0, 120, 4), batch=10,
                       test_batch=10, cache_size=8)
    _, res = run_engine(api, fleet, "vmap", True, True)
    g = res.telemetry.events[-1].gauges
    assert g["clients_materialized"] == fleet.materialized >= 4
    assert g["clients_cached"] == fleet.cached
    assert g["fleet_hits"] == fleet.hits >= 1


# ---------------------------------------------------------------------------
# recompile counters: traces counted, dispatches not; fused = once
# ---------------------------------------------------------------------------

def test_traced_counts_traces_not_dispatches():
    counts = {}
    f = jax.jit(traced("prog", counts, lambda x: x * 2.0))
    np.testing.assert_allclose(f(jnp.ones(3)), 2.0 * np.ones(3))
    f(jnp.ones(3))                      # cached dispatch: no new trace
    assert counts["prog"] == 1
    f(jnp.ones(4))                      # shape change forces a retrace
    assert counts["prog"] == 2


@pytest.mark.parametrize("bk", ["vmap", "mesh"])
def test_fused_programs_trace_once(onoff, bk):
    res = onoff[(bk, True)]["on"][1]
    tc = res.telemetry.trace_counts
    assert tc.get("fused_fill") == 1
    assert tc.get("fused_eval_shared") == 1
    assert all(v == 1 for v in tc.values()), tc
    events = res.telemetry.events
    assert events[0].recompiles.get("fused_fill") == 1
    for e in events[1:]:                # steady state: no retraces
        assert e.recompiles == {}


def test_injected_retrace_surfaces_in_round_events():
    class FakeBackend:
        def __init__(self):
            self.trace_counts = {}

    class FakeEngine:
        def __init__(self):
            self.backend = FakeBackend()
            self.stats = object()       # comm deltas read 0.0 defaults

    eng = FakeEngine()
    tel = Telemetry(TelemetryConfig(gauges=False, annotations=False))
    f = jax.jit(traced("prog", eng.backend.trace_counts, lambda x: x + 1))
    tel.start_run(eng)
    f(jnp.ones(3))
    assert tel.end_round(1, 0.0, eng).recompiles == {"prog": 1}
    f(jnp.ones(3))                      # cached: clean steady round
    assert tel.end_round(2, 0.0, eng).recompiles == {}
    f(jnp.ones(5))                      # injected shape-varying retrace
    assert tel.end_round(3, 0.0, eng).recompiles == {"prog": 1}


# ---------------------------------------------------------------------------
# sinks
# ---------------------------------------------------------------------------

def test_jsonl_sink_one_line_per_round(api, tmp_path):
    path = tmp_path / "rounds.jsonl"
    _, res = run_engine(api, tiny_clients(4, 120), "vmap", True,
                        {"sink": f"jsonl:{path}"})
    events = [json.loads(line) for line in path.read_text().splitlines()]
    assert [e["gen"] for e in events] == list(range(1, GENS + 1))
    for e in events:
        assert set(e) == {"gen", "round_s", "spans", "span_counts",
                          "recompiles", "gauges", "comm"}
    # the file mirrors the in-memory ring, event for event
    assert events[-1] == event_dict(res.telemetry.events[-1])


def test_memory_ring_capacity(api):
    _, res = run_engine(api, tiny_clients(4, 120), "vmap", True,
                        {"ring": 2})
    assert [e.gen for e in res.telemetry.events] == [GENS - 1, GENS]


def test_table_sink_rows():
    buf = io.StringIO()
    sink = TableSink(stream=buf)
    ev = RoundEvent(gen=1, round_s=0.5,
                    spans={"fill_train": 0.3, "fill_train/download": 0.1,
                           "eval": 0.05},
                    span_counts={"fill_train": 2},
                    recompiles={"fused_fill": 1},
                    gauges={"live_device_bytes": 2e6},
                    comm={"up_bytes": 1e6})
    sink.emit(ev)
    sink.emit(ev)
    lines = buf.getvalue().splitlines()
    assert len(lines) == 4              # header + rule + two rows
    assert lines[0].split()[0] == "gen"
    assert "0.400" in lines[2]          # fill_train + nested download


def test_sink_spec_validation():
    assert parse_sink_spec("memory") == ("memory", "")
    assert parse_sink_spec("table") == ("table", "")
    assert parse_sink_spec("jsonl:/tmp/x.jsonl") == ("jsonl", "/tmp/x.jsonl")
    with pytest.raises(ValueError):
        TelemetryConfig(sink="carrier_pigeon")
    with pytest.raises(ValueError):
        TelemetryConfig(sink="jsonl:")
    with pytest.raises(ValueError):
        TelemetryConfig(ring=0)
    with pytest.raises(ValueError):     # RunConfig coercion validates too
        RunConfig(telemetry={"sink": "nope"})


# ---------------------------------------------------------------------------
# gauge helpers shared with benchmarks/fed_nas.py
# ---------------------------------------------------------------------------

def test_steady_mean():
    assert steady_mean([]) is None
    assert steady_mean([2.5]) == 2.5
    assert steady_mean([10.0, 1.0, 3.0]) == 2.0


def test_peak_live_bytes_tracks_growth():
    pk = PeakLiveBytes()
    assert pk.peak == pk.baseline
    x = jnp.zeros((256, 256), jnp.float32)
    jax.block_until_ready(x)
    pk.sample("gen", "report")          # engine-callback signature
    assert pk.growth == pk.peak - pk.baseline >= 0
    assert pk.peak >= pk.baseline
    del x


def test_host_gauges_positive():
    assert live_device_bytes() >= 0
    assert host_rss_bytes() > 0


def test_null_telemetry_noop():
    assert not NULL_TELEMETRY.enabled
    with NULL_TELEMETRY.span("anything"):
        pass
    NULL_TELEMETRY.start_run(None)
    NULL_TELEMETRY.end_round(1, 0.0, None)
    with NULL_TELEMETRY.run_capture():
        pass
    assert NULL_TELEMETRY.result(None) is None
