"""Optimizers, schedules, and checkpoint roundtrip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import load_pytree, restore_latest, save_pytree
from repro.optim import (
    adamw_init, adamw_update, cosine_decay, round_decay, sgd_init, sgd_update,
)


def quad_loss(p):
    return jnp.sum((p["w"] - 3.0) ** 2) + jnp.sum((p["b"] + 1.0) ** 2)


@pytest.mark.parametrize("opt", ["sgd", "adamw"])
def test_optimizers_converge_on_quadratic(opt):
    params = {"w": jnp.zeros(4), "b": jnp.zeros(2)}
    state = sgd_init(params) if opt == "sgd" else adamw_init(params)
    for _ in range(200):
        g = jax.grad(quad_loss)(params)
        if opt == "sgd":
            params, state = sgd_update(params, g, state, 0.05, 0.5)
        else:
            params, state = adamw_update(params, g, state, 0.05, wd=0.0)
    assert float(quad_loss(params)) < 1e-2


def test_round_decay_matches_paper():
    # Table II: lr0 0.1, decay 0.995 per round
    assert float(round_decay(0.1, 0.995, 0)) == pytest.approx(0.1)
    assert float(round_decay(0.1, 0.995, 100)) == pytest.approx(
        0.1 * 0.995 ** 100)


def test_cosine_decay_warmup_and_floor():
    assert float(cosine_decay(1.0, 0, 100, warmup=10)) == pytest.approx(0.0)
    assert float(cosine_decay(1.0, 10, 100, warmup=10)) == pytest.approx(
        1.0, rel=1e-3)
    assert float(cosine_decay(1.0, 100, 100, warmup=10)) == pytest.approx(
        0.0, abs=1e-6)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"layers": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                       "b": jnp.ones((3,), jnp.bfloat16)},
            "step": jnp.int32(7)}
    path = save_pytree(str(tmp_path / "ckpt"), tree, step=7)
    restored = load_pytree(path, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_restore_latest_picks_newest(tmp_path):
    tree = {"w": jnp.zeros(3)}
    d = str(tmp_path / "ckpts")
    save_pytree(d, {"w": jnp.ones(3)}, step=1)
    save_pytree(d, {"w": jnp.full(3, 2.0)}, step=2)
    restored, step = restore_latest(d, tree)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(restored["w"]), 2.0)


def test_restore_latest_empty(tmp_path):
    restored, step = restore_latest(str(tmp_path / "nope"), {"w": jnp.zeros(1)})
    assert restored is None and step == -1


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = save_pytree(str(tmp_path / "c"), {"w": jnp.zeros((2, 2))}, step=0)
    with pytest.raises(ValueError):
        load_pytree(path, {"w": jnp.zeros((3, 3))})
