"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def rand(shape, dtype):
    x = RNG.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,kh,d", [
    (2, 128, 4, 4, 64),
    (1, 256, 4, 2, 128),
    (1, 384, 6, 1, 64),     # MQA, non-pow2 seq (384 = 3 x 128)
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention_sweep(dtype, b, s, h, kh, d, causal, window):
    q, k, v = (rand((b, s, h, d), dtype), rand((b, s, kh, d), dtype),
               rand((b, s, kh, d), dtype))
    out = ops.flash_attention(q, k, v, causal=causal, window=window)
    exp = ref.flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32),
        rtol=TOL[dtype], atol=TOL[dtype] * 5)


@pytest.mark.parametrize("b,nc,q,h,p,n", [
    (2, 4, 64, 3, 32, 16),
    (1, 2, 128, 2, 64, 64),
    (1, 8, 32, 1, 16, 8),
])
def test_ssd_scan_sweep(b, nc, q, h, p, n):
    xs = rand((b, nc, q, h, p), jnp.float32)
    a = -jnp.abs(rand((b, nc, q, h), jnp.float32)) * 0.1
    bm = rand((b, nc, q, n), jnp.float32)
    cm = rand((b, nc, q, n), jnp.float32)
    y_k, s_k = ops.ssd_scan(xs, a, bm, cm)
    y_r, s_r = ref.ssd_scan(xs, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=2e-4, atol=2e-4)


def test_ssd_scan_matches_chunked_model_path():
    """kernel == models/ssm.ssd_chunked (the xla 'backend') == oracle."""
    from repro.models.ssm import ssd_chunked
    b, s, h, p, n, chunk = 2, 256, 2, 32, 16, 64
    x = rand((b, s, h, p), jnp.float32)
    dt = jnp.abs(rand((b, s, h), jnp.float32)) * 0.2
    a_head = -jnp.abs(rand((h,), jnp.float32))
    bm = rand((b, s, n), jnp.float32)
    cm = rand((b, s, n), jnp.float32)
    y_x, s_x = ssd_chunked(x, dt, a_head, bm, cm, chunk=chunk, backend="xla")
    y_p, s_p = ssd_chunked(x, dt, a_head, bm, cm, chunk=chunk,
                           backend="pallas")
    np.testing.assert_allclose(np.asarray(y_x, np.float32),
                               np.asarray(y_p, np.float32),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_x), np.asarray(s_p),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,p", [(2, 1000), (8, 8192), (5, 100000)])
def test_fill_aggregate_sweep(dtype, m, p):
    cl = rand((m, p), dtype)
    mk = jnp.asarray(RNG.integers(0, 2, size=(m, p)), dtype)
    w = jnp.asarray(RNG.random(m).astype(np.float32))
    w = w / w.sum()
    prev = rand((p,), dtype)
    out = ops.fill_aggregate(cl, mk, w, prev)
    exp = ref.fill_aggregate(cl, mk, w, prev)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=TOL[dtype], atol=TOL[dtype])
    # prev-buffer donation must not change results: the kernel-level
    # aliasing path (input_output_aliases, exercised directly — the ops
    # wrapper's donating jit route is gated off-CPU)
    from repro.kernels import fill_aggregate as _fa
    donated = _fa.fill_aggregate(cl, mk, w, prev, interpret=True,
                                 donate_prev=True)
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(donated, np.float32))
    # and the ops wrapper accepts the flag on any host
    wrapped = ops.fill_aggregate(cl, mk, w, prev, donate_prev=True)
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(wrapped, np.float32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("e,c,d,f", [
    (2, 128, 256, 128),
    (4, 256, 256, 384),
    (1, 128, 512, 256),
])
def test_expert_gemm_sweep(dtype, e, c, d, f):
    x = rand((e, c, d), dtype)
    w = rand((e, d, f), dtype) * 0.05
    out = ops.expert_gemm(x, w)
    exp = ref.expert_gemm(x, w)
    scale = float(jnp.abs(exp.astype(jnp.float32)).max()) + 1e-6
    np.testing.assert_allclose(np.asarray(out, np.float32) / scale,
                               np.asarray(exp, np.float32) / scale,
                               rtol=TOL[dtype], atol=TOL[dtype] * 10)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("p", [1000, 8192, 100000])
def test_quantize_int8_sweep(dtype, p):
    """Pallas quantize/dequantize vs the jnp references: the int8 grids
    must match exactly (same round/clip math), dequant to fp tolerance."""
    x = rand((p,), dtype).astype(jnp.float32)
    scale = jnp.max(jnp.abs(x)) / 127.0
    q_k = ops.quantize_int8(x, scale)
    q_r = ref.quantize_int8(x, scale)
    assert q_k.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))
    d_k = ops.dequantize_int8(q_k, scale)
    d_r = ref.dequantize_int8(q_r, scale)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r),
                               rtol=0, atol=0)
    # roundtrip error bound: half a quantization step
    np.testing.assert_array_less(np.abs(np.asarray(d_k) - np.asarray(x)),
                                 float(scale) / 2 + 1e-7)


def test_quantize_int8_zero_and_extremes():
    x = jnp.asarray([0.0, 1.0, -1.0, 0.5, -0.49], jnp.float32)
    scale = jnp.float32(1.0 / 127.0)
    q = np.asarray(ops.quantize_int8(x, scale))
    np.testing.assert_array_equal(q, [0, 127, -127, 64, -62])
    # values beyond the grid clip instead of wrapping
    big = jnp.asarray([10.0, -10.0], jnp.float32)
    np.testing.assert_array_equal(np.asarray(ops.quantize_int8(big, scale)),
                                  [127, -127])


def test_expert_ffn_kernel_matches_moe_module():
    from repro.models.moe import expert_ffn as moe_ffn
    e, c, d, f = 2, 128, 128, 256
    experts = {"wi": rand((e, d, f), jnp.float32) * 0.05,
               "wg": rand((e, d, f), jnp.float32) * 0.05,
               "wo": rand((e, f, d), jnp.float32) * 0.05}
    x = rand((e, c, d), jnp.float32)
    np.testing.assert_allclose(np.asarray(ops.expert_ffn(experts, x)),
                               np.asarray(moe_ffn(experts, x)),
                               rtol=1e-4, atol=1e-5)
