"""Federated data partitioner invariants (hypothesis) + pipeline shapes."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # degrade to fixed-seed examples
    from _hyp_fallback import given, settings, strategies as st

from repro.data import (
    ClientDataset, batched, make_classification, make_clients, make_lm_stream,
    partition_dirichlet, partition_iid, partition_label,
)


@settings(max_examples=25, deadline=None)
@given(st.integers(10, 500), st.integers(1, 10), st.integers(0, 1000))
def test_iid_partition_is_disjoint_cover(n, k, seed):
    shards = partition_iid(seed, n, k)
    allidx = np.concatenate(shards)
    assert len(allidx) == n
    assert len(np.unique(allidx)) == n


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 10), st.integers(1, 10), st.integers(0, 1000))
def test_label_partition_exactly_cpc_distinct_classes(k, cpc, seed):
    """Every client holds data from EXACTLY cpc distinct classes (not
    "up to" — the old stack-based dealer could hand out duplicates when
    cpc did not divide the class count)."""
    labels = np.repeat(np.arange(10), 50)
    shards = partition_label(seed, labels, k, classes_per_client=cpc)
    allidx = np.concatenate([s for s in shards if len(s)])
    assert len(np.unique(allidx)) == len(allidx)          # disjoint
    for s in shards:
        assert len(np.unique(labels[s])) == cpc


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 10), st.integers(1, 10), st.integers(0, 1000))
def test_label_partition_full_coverage_when_all_classes_held(k, cpc, seed):
    """Whenever k*cpc >= #classes the balanced quota deal guarantees
    every class a holder, hence full data coverage; below that bound
    exactly the unheld classes' data is dropped."""
    labels = np.repeat(np.arange(10), 30)
    shards = partition_label(seed, labels, k, classes_per_client=cpc)
    allidx = np.concatenate([s for s in shards if len(s)])
    held = np.unique(labels[allidx])
    if k * cpc >= 10:
        assert len(allidx) == len(labels)
        assert len(held) == 10
    else:
        assert len(held) == k * cpc       # distinct classes, no repeats
        keep = np.isin(labels, held)
        assert len(allidx) == int(keep.sum())


def test_label_partition_rejects_cpc_above_class_count():
    labels = np.repeat(np.arange(10), 5)
    with pytest.raises(ValueError, match="classes_per_client"):
        partition_label(0, labels, 4, classes_per_client=11)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 8), st.floats(0.1, 10.0), st.integers(0, 100))
def test_dirichlet_partition_covers(k, alpha, seed):
    labels = np.repeat(np.arange(10), 30)
    shards = partition_dirichlet(seed, labels, k, alpha)
    allidx = np.concatenate([s for s in shards if len(s)])
    assert len(np.unique(allidx)) == len(allidx) == len(labels)


def test_batched_shapes_and_drop_tail():
    x = np.arange(107, dtype=np.float32)[:, None]
    y = np.arange(107)
    xb, yb = batched(x, y, 10)
    assert xb.shape == (10, 10, 1) and yb.shape == (10, 10)


def test_client_dataset_split():
    x, y = make_classification(0, 500, image=8)
    c = ClientDataset(0, x, y, batch=25, test_batch=25)
    assert c.train[0].shape[1] == 25
    assert c.test[0].shape[1] == 25
    assert c.weight == c.n_train > 0


def test_classification_learnable_structure():
    """Same class => prototypes correlate; 0 noise => exactly equal."""
    x, y = make_classification(0, 200, image=8, noise=0.0)
    i, j = np.where(y == y[0])[0][:2]
    np.testing.assert_allclose(x[i], x[j])


def test_lm_stream_markov_structure():
    x, y = make_lm_stream(0, 20, 50, vocab=97, order_noise=0.0)
    assert x.shape == (20, 50) and y.shape == (20, 50)
    np.testing.assert_array_equal(x[:, 1:], y[:, :-1])  # y is next-token
    # deterministic successor: same token always followed by same token
    tok = x[0, 0]
    followers = {int(y[r, c]) for r in range(20) for c in range(50)
                 if x[r, c] == tok}
    assert len(followers) == 1


def test_make_clients_weights_sum():
    x, y = make_classification(1, 400, image=8)
    shards = partition_iid(1, 400, 4)
    clients = make_clients(x, y, shards, batch=20, test_batch=20)
    assert len(clients) == 4
    assert sum(c.n_train for c in clients) <= 400
