"""Federated data partitioner invariants (hypothesis) + pipeline shapes."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # degrade to fixed-seed examples
    from _hyp_fallback import given, settings, strategies as st

from repro.data import (
    ClientDataset, Partition, VirtualClassification, batched,
    make_classification, make_clients, make_fleet, make_lm_stream,
    partition_dirichlet, partition_iid, partition_label,
)


@settings(max_examples=25, deadline=None)
@given(st.integers(10, 500), st.integers(1, 10), st.integers(0, 1000))
def test_iid_partition_is_disjoint_cover(n, k, seed):
    shards = partition_iid(seed, n, k)
    allidx = np.concatenate(shards)
    assert len(allidx) == n
    assert len(np.unique(allidx)) == n


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 10), st.integers(1, 10), st.integers(0, 1000))
def test_label_partition_exactly_cpc_distinct_classes(k, cpc, seed):
    """Every client holds data from EXACTLY cpc distinct classes (not
    "up to" — the old stack-based dealer could hand out duplicates when
    cpc did not divide the class count)."""
    labels = np.repeat(np.arange(10), 50)
    shards = partition_label(seed, labels, k, classes_per_client=cpc)
    allidx = np.concatenate([s for s in shards if len(s)])
    assert len(np.unique(allidx)) == len(allidx)          # disjoint
    for s in shards:
        assert len(np.unique(labels[s])) == cpc


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 10), st.integers(1, 10), st.integers(0, 1000))
def test_label_partition_full_coverage_when_all_classes_held(k, cpc, seed):
    """Whenever k*cpc >= #classes the balanced quota deal guarantees
    every class a holder, hence full data coverage; below that bound
    exactly the unheld classes' data is dropped."""
    labels = np.repeat(np.arange(10), 30)
    shards = partition_label(seed, labels, k, classes_per_client=cpc)
    allidx = np.concatenate([s for s in shards if len(s)])
    held = np.unique(labels[allidx])
    if k * cpc >= 10:
        assert len(allidx) == len(labels)
        assert len(held) == 10
    else:
        assert len(held) == k * cpc       # distinct classes, no repeats
        keep = np.isin(labels, held)
        assert len(allidx) == int(keep.sum())


def test_label_partition_rejects_cpc_above_class_count():
    labels = np.repeat(np.arange(10), 5)
    with pytest.raises(ValueError, match="classes_per_client"):
        partition_label(0, labels, 4, classes_per_client=11)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 8), st.floats(0.1, 10.0), st.integers(0, 100))
def test_dirichlet_partition_covers(k, alpha, seed):
    labels = np.repeat(np.arange(10), 30)
    shards = partition_dirichlet(seed, labels, k, alpha)
    allidx = np.concatenate([s for s in shards if len(s)])
    assert len(np.unique(allidx)) == len(allidx) == len(labels)


def test_batched_shapes_and_drop_tail():
    x = np.arange(107, dtype=np.float32)[:, None]
    y = np.arange(107)
    xb, yb = batched(x, y, 10)
    assert xb.shape == (10, 10, 1) and yb.shape == (10, 10)


def test_client_dataset_split():
    x, y = make_classification(0, 500, image=8)
    c = ClientDataset(0, x, y, batch=25, test_batch=25)
    assert c.train[0].shape[1] == 25
    assert c.test[0].shape[1] == 25
    assert c.weight == c.n_train > 0


def test_classification_learnable_structure():
    """Same class => prototypes correlate; 0 noise => exactly equal."""
    x, y = make_classification(0, 200, image=8, noise=0.0)
    i, j = np.where(y == y[0])[0][:2]
    np.testing.assert_allclose(x[i], x[j])


def test_lm_stream_markov_structure():
    x, y = make_lm_stream(0, 20, 50, vocab=97, order_noise=0.0)
    assert x.shape == (20, 50) and y.shape == (20, 50)
    np.testing.assert_array_equal(x[:, 1:], y[:, :-1])  # y is next-token
    # deterministic successor: same token always followed by same token
    tok = x[0, 0]
    followers = {int(y[r, c]) for r in range(20) for c in range(50)
                 if x[r, c] == tok}
    assert len(followers) == 1


def test_make_clients_weights_sum():
    x, y = make_classification(1, 400, image=8)
    shards = partition_iid(1, 400, 4)
    clients = make_clients(x, y, shards, batch=20, test_batch=20)
    assert len(clients) == 4
    assert sum(c.n_train for c in clients) <= 400


# ---------------------------------------------------------------------------
# Lazy index-space partitions: bit-exact equivalence with the historical
# eager implementations (verbatim copies below), large-fleet invariants,
# determinism, and the dirichlet min_samples guard.

def _eager_iid(seed, n, num_clients):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    return [np.sort(s) for s in np.array_split(perm, num_clients)]


def _eager_label(seed, labels, num_clients, classes_per_client=5):
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    n_classes = len(classes)
    cpc = classes_per_client
    base, extra = divmod(num_clients * cpc, n_classes)
    quota = np.full(n_classes, base, dtype=np.int64)
    quota[rng.permutation(n_classes)[:extra]] += 1
    client_classes = []
    for _ in range(num_clients):
        pick = np.lexsort((rng.random(n_classes), -quota))[:cpc]
        quota[pick] -= 1
        client_classes.append(set(classes[pick].tolist()))
    holders = {c: [i for i, cc in enumerate(client_classes) if c in cc]
               for c in classes}
    out = [[] for _ in range(num_clients)]
    for c in classes:
        if not holders[c]:
            continue
        idx = np.where(labels == c)[0]
        hs = holders[c]
        idx = rng.permutation(idx)
        for h, shard in zip(hs, np.array_split(idx, len(hs))):
            out[h].extend(shard.tolist())
    return [np.sort(np.asarray(s, dtype=np.int64)) for s in out]


def _eager_dirichlet(seed, labels, num_clients, alpha=0.5):
    rng = np.random.default_rng(seed)
    out = [[] for _ in range(num_clients)]
    for c in np.unique(labels):
        idx = rng.permutation(np.where(labels == c)[0])
        probs = rng.dirichlet([alpha] * num_clients)
        cuts = (np.cumsum(probs)[:-1] * len(idx)).astype(int)
        for h, shard in enumerate(np.split(idx, cuts)):
            out[h].extend(shard.tolist())
    return [np.sort(np.asarray(s, dtype=np.int64)) for s in out]


def _assert_shards_identical(lazy, eager):
    assert len(lazy) == len(eager)
    sizes = lazy.shard_sizes()
    for i, ref in enumerate(eager):
        got = lazy[i]
        assert got.dtype == ref.dtype, (i, got.dtype, ref.dtype)
        np.testing.assert_array_equal(got, ref)
        assert sizes[i] == len(ref)


@settings(max_examples=25, deadline=None)
@given(st.integers(10, 400), st.integers(1, 12), st.integers(0, 10_000))
def test_iid_lazy_matches_eager_bit_for_bit(n, k, seed):
    _assert_shards_identical(partition_iid(seed, n, k),
                             _eager_iid(seed, n, k))


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 12), st.integers(1, 10), st.integers(0, 10_000))
def test_label_lazy_matches_eager_bit_for_bit(k, cpc, seed):
    labels = np.repeat(np.arange(10), 40)
    _assert_shards_identical(
        partition_label(seed, labels, k, classes_per_client=cpc),
        _eager_label(seed, labels, k, classes_per_client=cpc))


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 12), st.floats(0.05, 10.0), st.integers(0, 10_000))
def test_dirichlet_lazy_matches_eager_bit_for_bit(k, alpha, seed):
    labels = np.repeat(np.arange(10), 30)
    _assert_shards_identical(partition_dirichlet(seed, labels, k, alpha),
                             _eager_dirichlet(seed, labels, k, alpha))


def test_partition_sequence_protocol():
    p = partition_iid(3, 100, 7)
    assert isinstance(p, Partition) and len(p) == 7
    np.testing.assert_array_equal(p[-1], p[6])
    assert [len(s) for s in p[2:5]] == list(p.shard_sizes()[2:5])
    with pytest.raises(IndexError):
        p[7]
    assert p.nbytes > 0
    mat = p.materialize()
    assert len(mat) == 7
    np.testing.assert_array_equal(np.sort(np.concatenate(mat)),
                                  np.arange(100))


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000))
def test_iid_partition_large_fleet_invariants(seed):
    """10^5 clients: disjoint full cover, shard_sizes consistent, and
    construction stores only O(n) integers — no per-client Python
    objects."""
    n, k = 400_000, 100_000
    p = partition_iid(seed, n, k)
    sizes = p.shard_sizes()
    assert len(sizes) == k and sizes.sum() == n
    assert sizes.min() >= n // k and sizes.max() <= n // k + 1
    # spot-materialized shards agree with the size vector and are
    # disjoint across a sampled set of clients
    rng = np.random.default_rng(seed)
    cids = rng.choice(k, size=64, replace=False)
    got = [p.indices_for(int(c)) for c in cids]
    assert all(len(g) == sizes[c] for g, c in zip(got, cids))
    cat = np.concatenate(got)
    assert len(np.unique(cat)) == len(cat)
    assert p.nbytes < 3 * n * 8       # perm + cuts, not shard lists


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 10_000))
def test_label_partition_large_fleet_invariants(seed):
    """10^4 clients x exactly-5-classes: every sampled client sees
    exactly cpc distinct classes; the full cover holds by shard sizes."""
    k, cpc = 10_000, 5
    labels = np.repeat(np.arange(10), 5_000)       # 5k samples/class
    p = partition_label(seed, labels, k, classes_per_client=cpc)
    sizes = p.shard_sizes()
    assert sizes.sum() == len(labels)              # k*cpc >= C: full cover
    rng = np.random.default_rng(seed)
    for c in rng.choice(k, size=32, replace=False):
        s = p.indices_for(int(c))
        assert len(s) == sizes[c]
        assert len(np.unique(labels[s])) == cpc


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 10_000))
def test_dirichlet_partition_large_fleet_covers(seed):
    k = 10_000
    labels = np.repeat(np.arange(10), 200)
    p = partition_dirichlet(seed, labels, k, alpha=0.5)
    assert p.shard_sizes().sum() == len(labels)
    # disjointness across every nonempty shard (2k samples total, cheap)
    cat = np.concatenate([s for s in p if len(s)])
    assert len(np.unique(cat)) == len(cat) == len(labels)


def test_partitioners_deterministic_under_fixed_seed():
    labels = np.repeat(np.arange(10), 100)
    for build in (lambda s: partition_iid(s, 1000, 37),
                  lambda s: partition_label(s, labels, 37),
                  lambda s: partition_dirichlet(s, labels, 37, 0.3)):
        a, b = build(11), build(11)
        for i in (0, 17, 36):
            np.testing.assert_array_equal(a[i], b[i])
        assert not all(np.array_equal(x, y)
                       for x, y in zip(build(11), build(12)))


# -- dirichlet min_samples guard (regression: empty clients used to pass
# silently and explode much later in batched()/stacking) ------------------

def test_dirichlet_default_still_permits_empty_clients():
    """min_samples=0 keeps the historical behavior (and RNG stream) bit
    for bit — including the silent empty shard this seed produces."""
    labels = np.repeat(np.arange(10), 10)
    p = partition_dirichlet(0, labels, 30, alpha=0.3)
    assert int(p.shard_sizes().min()) == 0
    _assert_shards_identical(p, _eager_dirichlet(0, labels, 30, alpha=0.3))


def test_dirichlet_min_samples_rescues_by_redraw():
    labels = np.repeat(np.arange(10), 10)
    assert int(partition_dirichlet(2, labels, 30,
                                   alpha=0.3).shard_sizes().min()) == 0
    p = partition_dirichlet(2, labels, 30, alpha=0.3, min_samples=1)
    assert int(p.shard_sizes().min()) >= 1
    assert p.shard_sizes().sum() == len(labels)


def test_dirichlet_min_samples_fails_loudly_when_impossible():
    labels = np.repeat(np.arange(10), 4)           # 40 samples...
    with pytest.raises(ValueError, match="min_samples"):
        partition_dirichlet(0, labels, 50, alpha=0.3,
                            min_samples=1, resample=5)   # ...50 clients


# ---------------------------------------------------------------------------
# Lazy client fleet + virtual sample source

def test_fleet_matches_make_clients_bit_for_bit():
    x, y = make_classification(5, 300, image=8)
    part = partition_iid(5, 300, 6)
    eager = make_clients(x, y, part.materialize(), batch=10, test_batch=10)
    fleet = make_fleet(x, y, part, batch=10, test_batch=10)
    assert len(fleet) == len(eager)
    for c_lazy, c_eager in zip(fleet, eager):
        assert c_lazy.cid == c_eager.cid
        assert c_lazy.weight == c_eager.weight
        for split in ("train", "test"):
            for a, b in zip(getattr(c_lazy, split), getattr(c_eager, split)):
                np.testing.assert_array_equal(a, b)


def test_fleet_lru_evicts_and_refreshes():
    x, y = make_classification(5, 300, image=8)
    fleet = make_fleet(x, y, partition_iid(5, 300, 10), batch=5,
                       test_batch=5, cache_size=3)
    for cid in (0, 1, 2):
        fleet[cid]
    fleet[0]                  # refresh 0: now 1 is least-recently-used
    fleet[3]                  # evicts 1
    assert fleet.materialized == 4 and fleet.cached == 3
    assert set(fleet._cache) == {0, 2, 3}
    fleet[1]                  # rebuild after eviction
    assert fleet.materialized == 5
    with pytest.raises(IndexError):
        fleet[10]


def test_virtual_classification_per_index_deterministic():
    src = VirtualClassification(9, 1_000_000, image=8)
    xa, ya = src.take([5, 123_456, 999_999])
    xb, yb = src.take([999_999, 5])        # different batch, same samples
    np.testing.assert_array_equal(xa[0], xb[1])
    np.testing.assert_array_equal(xa[2], xb[0])
    assert ya.dtype == np.int32 and xa.dtype == np.float32
    assert xa.shape == (3, 8, 8, 3)
    with pytest.raises(IndexError):
        src.take([1_000_000])


def test_virtual_fleet_scales_without_materialization():
    """A 10^5-client fleet over a virtual source: accessing a handful of
    clients touches only their samples and only they are ever built."""
    from repro.data import ClientFleet
    k, spc = 100_000, 8
    src = VirtualClassification(4, k * spc, image=8)
    fleet = ClientFleet(src, partition_iid(4, k * spc, k), batch=2,
                        test_batch=2, cache_size=8)
    for cid in (0, 54_321, 99_999):
        c = fleet[cid]
        assert c.train[0].shape[1] == 2
    assert fleet.materialized == 3 and fleet.cached == 3
