"""Model-substrate behaviour: prefill/decode consistency per family,
supernet branch semantics, RoPE variants, sliding window."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as tr
from repro.models.layers import apply_rope, cross_entropy, fused_cross_entropy

RNG = jax.random.PRNGKey(0)


def consistency(arch, steps=12, window=0, atol=5e-4):
    cfg = get_config(arch, smoke=True)
    params = tr.init_params(RNG, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, steps), 0,
                              cfg.vocab_size)
    prefix = None
    enc_out = None
    if cfg.family in ("vlm", "audio"):
        prefix = jnp.ones((2, cfg.num_prefix, cfg.d_model), jnp.float32) * 0.1
    full, _, _ = tr.forward(params, cfg, toks, prefix=prefix, window=window)
    if cfg.family == "audio":
        enc_out = tr.encode(params, cfg, prefix)
        prefix_for_cache = None
    cache = tr.prefill_cache(params, cfg, toks[:, :-1], window=window,
                             cache_len=2 * steps,
                             enc_out=enc_out)
    if cfg.family == "vlm":
        pytest.skip("vlm prefill-cache path needs the prefix replay; "
                    "covered by test_vlm_prefix_shapes")
    dec, _ = tr.decode_step(params, cfg, toks[:, -1:], cache, window=window)
    np.testing.assert_allclose(np.asarray(full[:, -1]), np.asarray(dec[:, 0]),
                               rtol=1e-3, atol=atol)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "chatglm3-6b",
                                  "starcoder2-3b", "deepseek-67b"])
def test_dense_prefill_decode_consistency(arch):
    consistency(arch)


def test_ssm_prefill_decode_consistency():
    # chunk boundary: steps must be compatible with decode recurrence
    cfg = get_config("mamba2-780m", smoke=True)
    params = tr.init_params(RNG, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    # decode token-by-token and compare with nothing — finite check +
    # recurrent-vs-chunked equivalence is covered in test_kernels; here we
    # check the stack-level decode runs and evolves state
    cache = tr.init_cache(params, cfg, 2, 16)
    outs = []
    for i in range(4):
        logits, cache = tr.decode_step(params, cfg, toks[:, i:i + 1], cache)
        outs.append(logits)
    assert not bool(jnp.isnan(jnp.stack(outs)).any())
    assert bool(jnp.any(cache["layers"]["ssm"]["state"] != 0)) if "ssm" in \
        cache["layers"] else True


def test_ssm_chunked_equals_stepwise():
    """forward (chunked SSD) last-token logits == recurrent decode replay."""
    from repro.models.ssm import CHUNK
    cfg = get_config("mamba2-780m", smoke=True)
    params = tr.init_params(RNG, cfg)
    steps = 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, steps), 0,
                              cfg.vocab_size)
    full, _, _ = tr.forward(params, cfg, toks)
    cache = tr.init_cache(params, cfg, 1, steps)
    for i in range(steps):
        dec, cache = tr.decode_step(params, cfg, toks[:, i:i + 1], cache)
    np.testing.assert_allclose(np.asarray(full[:, -1]), np.asarray(dec[:, 0]),
                               rtol=2e-3, atol=2e-3)


def test_hybrid_decode_matches_forward():
    cfg = get_config("zamba2-2.7b", smoke=True)
    params = tr.init_params(RNG, cfg)
    steps = 8
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, steps), 0,
                              cfg.vocab_size)
    full, _, _ = tr.forward(params, cfg, toks)
    cache = tr.init_cache(params, cfg, 1, 16)
    for i in range(steps):
        dec, cache = tr.decode_step(params, cfg, toks[:, i:i + 1], cache)
    np.testing.assert_allclose(np.asarray(full[:, -1]), np.asarray(dec[:, 0]),
                               rtol=2e-3, atol=2e-3)


def test_whisper_decode_matches_forward():
    cfg = get_config("whisper-large-v3", smoke=True)
    params = tr.init_params(RNG, cfg)
    steps = 8
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, steps), 0,
                              cfg.vocab_size)
    frames = jax.random.normal(jax.random.PRNGKey(5),
                               (2, cfg.num_prefix, cfg.d_model)) * 0.1
    full, _, _ = tr.forward(params, cfg, toks, prefix=frames)
    enc_out = tr.encode(params, cfg, frames)
    cache = tr.prefill_cache(params, cfg, toks[:, :-1], cache_len=16,
                             enc_out=enc_out)
    dec, _ = tr.decode_step(params, cfg, toks[:, -1:], cache)
    np.testing.assert_allclose(np.asarray(full[:, -1]), np.asarray(dec[:, 0]),
                               rtol=1e-3, atol=5e-4)


def test_vlm_prefix_shapes():
    cfg = get_config("internvl2-1b", smoke=True)
    params = tr.init_params(RNG, cfg)
    toks = jnp.zeros((2, 16), jnp.int32)
    patches = jnp.ones((2, cfg.num_prefix, cfg.d_model), jnp.float32)
    logits, _, _ = tr.forward(params, cfg, toks, prefix=patches)
    # logits are over token positions only (prefix stripped)
    assert logits.shape == (2, 16, cfg.vocab_size)


def test_sliding_window_restricts_context():
    cfg = get_config("qwen1.5-0.5b", smoke=True).replace(num_layers=1)
    params = tr.init_params(RNG, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(6), (1, 32), 0,
                              cfg.vocab_size)
    full, _, _ = tr.forward(params, cfg, toks, window=0)
    win, _, _ = tr.forward(params, cfg, toks, window=8)
    # early positions (inside window) agree, late positions differ
    np.testing.assert_allclose(np.asarray(full[:, :8]), np.asarray(win[:, :8]),
                               rtol=1e-4, atol=1e-5)
    assert float(jnp.abs(full[:, -1] - win[:, -1]).max()) > 1e-4


def test_supernet_branches_differ_and_identity_skips():
    cfg = get_config("qwen1.5-0.5b", smoke=True).replace(supernet=True)
    params = tr.init_params(RNG, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(7), (2, 16), 0,
                              cfg.vocab_size)
    outs = {}
    for b in range(4):
        key = jnp.full((cfg.num_layers,), b, jnp.int32)
        outs[b], _, _ = tr.forward(params, cfg, toks, choice_key=key)
    # all four branches give distinct outputs
    for i in range(4):
        for j in range(i + 1, 4):
            assert float(jnp.abs(outs[i] - outs[j]).max()) > 1e-5, (i, j)
    # all-identity == embedding -> final norm -> unembed (no layer effect):
    # compare against a 0-layer model with the same embedding
    cfg0 = cfg.replace(num_layers=0, supernet=False)
    p0 = {"embed": params["embed"], "final_ln": params["final_ln"],
          "layers": jax.tree.map(lambda x: x[:0],
                                 jax.tree.map(lambda x: x[:, 0], params["layers"]))}
    out0, _, _ = tr.forward(p0, cfg0, toks)
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(out0),
                               rtol=1e-4, atol=1e-5)


def test_rope_2d_rotates_half():
    x = jax.random.normal(RNG, (1, 8, 2, 16))
    pos = jnp.arange(8)[None, :]
    full = apply_rope(x, pos, style="1d")
    half = apply_rope(x, pos, style="2d")
    # 2d: second half of head dim is pass-through
    np.testing.assert_allclose(np.asarray(half[..., 8:]),
                               np.asarray(x[..., 8:]))
    assert float(jnp.abs(full[..., 8:] - x[..., 8:]).max()) > 1e-4
    # position 0 unrotated everywhere
    np.testing.assert_allclose(np.asarray(full[:, 0]), np.asarray(x[:, 0]),
                               rtol=1e-5, atol=1e-6)


def test_chunked_attention_backend_matches_xla():
    cfg = get_config("chatglm3-6b", smoke=True)   # GQA kv=2 + 2d rope
    params = tr.init_params(RNG, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(11), (2, 100), 0,
                              cfg.vocab_size)
    lx, _, _ = tr.forward(params, cfg, toks, backend="xla")
    lc, _, _ = tr.forward(params, cfg, toks, backend="chunked")
    np.testing.assert_allclose(np.asarray(lx), np.asarray(lc), rtol=1e-5,
                               atol=1e-5)
    lxw, _, _ = tr.forward(params, cfg, toks, backend="xla", window=16)
    lcw, _, _ = tr.forward(params, cfg, toks, backend="chunked", window=16)
    np.testing.assert_allclose(np.asarray(lxw), np.asarray(lcw), rtol=1e-5,
                               atol=1e-5)


def test_fused_ce_matches_naive():
    rng = jax.random.PRNGKey(8)
    h = jax.random.normal(rng, (2, 32, 64))
    table = jax.random.normal(jax.random.PRNGKey(9), (100, 64)) * 0.1
    labels = jax.random.randint(jax.random.PRNGKey(10), (2, 32), 0, 100)
    naive = cross_entropy(jnp.einsum("bsd,vd->bsv", h, table), labels)
    fused = fused_cross_entropy(h, table, labels, chunk=16)
    np.testing.assert_allclose(float(naive), float(fused), rtol=1e-5)
    # gradients agree too
    g1 = jax.grad(lambda t: cross_entropy(
        jnp.einsum("bsd,vd->bsv", h, t), labels))(table)
    g2 = jax.grad(lambda t: fused_cross_entropy(h, t, labels, chunk=16))(table)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4,
                               atol=1e-6)
