"""FedEngine: strategy x execution-backend matrix.

Backend parity ("loop" vs "vmap" vs "mesh") on the smoke CIFAR supernet:
identical CommStats, per-generation test errors, and master params within
1e-5; batched fill-aggregation against the per-upload oracle (XLA and
Pallas routes); evaluation-phase communication accounting; ClientBatch
stacking invariants; and the legacy ``rt_enas.run`` / ``offline_enas.run``
shims.  The mesh backend shards over however many local devices exist —
CI additionally runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the sharded
paths are exercised on a real 8-way mesh (and
``test_mesh_parity_forced_8_devices`` forces that in a subprocess even
for single-device local runs).
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import make_api, offline_enas, rt_enas
from repro.core.aggregate import fill_aggregate, fill_aggregate_stacked
from repro.data import make_classification, make_clients, partition_iid
from repro.data.pipeline import ClientBatch, shape_buckets
from repro.engine import (
    BYTES_PER_PARAM, ERROR_COUNT_BYTES, FedAvgBaseline, FedEngine,
    OfflineNas, RealTimeNas, RunConfig,
)

PARITY_BACKENDS = ("loop", "vmap", "mesh")


def tiny_clients(num_clients=8, n=480, seed=0):
    x, y = make_classification(seed, n, image=8, signal=1.5, noise=0.5)
    return make_clients(x, y, partition_iid(seed, n, num_clients),
                        batch=20, test_batch=20)


@pytest.fixture(scope="module")
def api():
    return make_api(get_config("cifar-supernet", smoke=True))


def max_leaf_diff(a, b):
    return max(float(jnp.abs(jnp.asarray(x) - jnp.asarray(y)).max())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# backend parity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def rt_parity(api):
    clients = tiny_clients()
    out = {}
    for bk in PARITY_BACKENDS:
        eng = FedEngine(api, clients,
                        RunConfig(population=4, generations=2, seed=0,
                                  lr0=0.01, backend=bk))
        out[bk] = (eng.run(), eng.backend.dispatches)
    return out


@pytest.mark.parametrize("bk", ["vmap", "mesh"])
def test_rt_backends_same_master(rt_parity, bk):
    loop, other = rt_parity["loop"][0], rt_parity[bk][0]
    assert max_leaf_diff(loop.extras["final_master"],
                         other.extras["final_master"]) <= 1e-5


@pytest.mark.parametrize("bk", ["vmap", "mesh"])
def test_rt_backends_same_errors_per_generation(rt_parity, bk):
    loop, other = rt_parity["loop"][0], rt_parity[bk][0]
    for a, b in zip(loop.reports, other.reports):
        np.testing.assert_allclose(a.objs, b.objs, atol=1e-5)
        assert a.best_err == pytest.approx(b.best_err, abs=1e-5)


@pytest.mark.parametrize("bk", ["vmap", "mesh"])
def test_rt_backends_same_comm_stats(rt_parity, bk):
    loop, other = rt_parity["loop"][0], rt_parity[bk][0]
    assert dataclasses.asdict(loop.stats) == dataclasses.asdict(other.stats)


@pytest.mark.slow
def test_vmap_dispatches_are_constant_in_clients(api):
    """The vectorized backend's dispatch count must not grow with the
    number of participating clients (the loop backend's does)."""
    counts = {}
    for m in (4, 8):
        eng = FedEngine(api, tiny_clients(num_clients=m, n=240 * m // 4),
                        RunConfig(population=4, generations=1, seed=0,
                                  backend="vmap"))
        eng.run()
        counts[m] = eng.backend.dispatches
    assert counts[4] == counts[8]
    eng = FedEngine(api, tiny_clients(num_clients=8),
                    RunConfig(population=4, generations=1, seed=0,
                              backend="loop"))
    eng.run()
    assert eng.backend.dispatches > 3 * counts[8]


@pytest.mark.slow
def test_mesh_dispatches_constant_in_clients_and_below_nonfused_vmap(api):
    """The mesh backend batches the whole population into O(#buckets)
    sharded dispatches per phase — constant in clients AND (on the
    non-fused path, where the vmap backend pays O(population)) below the
    vmap backend's count.  Fused, both collapse to the same constant —
    see test_fused_dispatches_per_generation."""
    counts = {}
    for m in (4, 8):
        eng = FedEngine(api, tiny_clients(num_clients=m, n=240 * m // 4),
                        RunConfig(population=4, generations=1, seed=0,
                                  backend="mesh", fused=False))
        eng.run()
        counts[m] = eng.backend.dispatches
    assert counts[4] == counts[8]
    eng = FedEngine(api, tiny_clients(num_clients=8),
                    RunConfig(population=4, generations=1, seed=0,
                              backend="vmap", fused=False))
    eng.run()
    assert counts[8] < eng.backend.dispatches


# ---------------------------------------------------------------------------
# fused-generation execution (RunConfig.fused, the default)
# ---------------------------------------------------------------------------

# RealTimeNas issues train_fill twice on gen 1 (parents + offspring) and
# once per later gen, plus one eval_shared per gen; fused, each of those
# is exactly ONE dispatch regardless of clients, population and shape
# buckets — the dispatch-count regression bound the fused path claims.
def fused_dispatch_bound(generations: int) -> int:
    return 2 * generations + 1


@pytest.mark.parametrize("bk", ["vmap", "mesh"])
def test_fused_dispatches_per_generation(api, bk):
    gens = 2
    eng = FedEngine(api, tiny_clients(),
                    RunConfig(population=4, generations=gens, seed=0,
                              backend=bk))
    eng.run()
    assert eng.backend.dispatches == fused_dispatch_bound(gens)


def ragged_clients():
    """Two shape buckets: 4 clients with 60-sample shards and 2 with
    100-sample shards (train stacks of 2 vs 4 batches of 20)."""
    x, y = make_classification(3, 440, image=8, signal=1.5, noise=0.5)
    shards = [np.arange(60) + 60 * i for i in range(4)] \
        + [240 + np.arange(100), 340 + np.arange(100)]
    return make_clients(x, y, shards, batch=20, test_batch=20)


@pytest.mark.slow
def test_fused_dispatches_bounded_by_buckets_and_ragged_parity(api):
    """Multi-bucket client sets stay within the fused dispatch bound
    (the bucket loop runs inside the program) and agree with the loop
    reference — ragged groups exercise the weight-0 padding rows."""
    clients = ragged_clients()
    gens = 2
    out = {}
    for bk in ("loop", "vmap", "mesh"):
        eng = FedEngine(api, clients,
                        RunConfig(population=3, generations=gens, seed=0,
                                  lr0=0.01, backend=bk))
        out[bk] = eng.run()
        if bk != "loop":
            assert eng.backend.dispatches == fused_dispatch_bound(gens)
    for bk in ("vmap", "mesh"):
        assert dataclasses.asdict(out["loop"].stats) == \
            dataclasses.asdict(out[bk].stats)
        assert max_leaf_diff(out["loop"].extras["final_master"],
                             out[bk].extras["final_master"]) <= 1e-5
        for a, b in zip(out["loop"].reports, out[bk].reports):
            np.testing.assert_allclose(a.objs, b.objs, atol=1e-5)


@pytest.mark.slow
@pytest.mark.parametrize("bk", ["vmap", "mesh"])
def test_fused_vs_nonfused_parity(api, bk):
    """The fused path must reproduce the per-bucket path: identical
    CommStats, zero error diff and master params within 1e-6."""
    clients = tiny_clients()
    out = {}
    for fused in (False, True):
        eng = FedEngine(api, clients,
                        RunConfig(population=4, generations=2, seed=0,
                                  lr0=0.01, backend=bk, fused=fused))
        out[fused] = eng.run()
    assert dataclasses.asdict(out[False].stats) == \
        dataclasses.asdict(out[True].stats)
    for a, b in zip(out[False].reports, out[True].reports):
        np.testing.assert_array_equal(a.objs, b.objs)
    assert max_leaf_diff(out[False].extras["final_master"],
                         out[True].extras["final_master"]) <= 1e-6


@pytest.mark.slow
def test_fused_vs_nonfused_parity_pallas(api):
    """The partially-fused pallas route (one SGD program, Algorithm 3 in
    the kernel) agrees with the non-fused pallas path — both normalize
    weights once (``fill_aggregate_stacked(total=...)``), so the only
    difference is the kernel's row-reduction grouping."""
    clients = tiny_clients()
    out = {}
    for fused in (False, True):
        out[fused] = FedEngine(
            api, clients,
            RunConfig(population=4, generations=2, seed=0, lr0=0.01,
                      backend="vmap", fused=fused,
                      aggregate_backend="pallas")).run()
    assert dataclasses.asdict(out[False].stats) == \
        dataclasses.asdict(out[True].stats)
    for a, b in zip(out[False].reports, out[True].reports):
        np.testing.assert_allclose(a.objs, b.objs, atol=1e-6)
    assert max_leaf_diff(out[False].extras["final_master"],
                         out[True].extras["final_master"]) <= 1e-6


@pytest.mark.slow
def test_fused_offline_and_fedavg_parity(api):
    """The fused fedavg-population / eval-paired paths (OfflineNas) and
    the fused FedAvg baseline agree with their non-fused selves."""
    clients = tiny_clients(num_clients=4, n=240)
    key = np.array([1, 0, 2, 3], np.int32)
    for strat in (lambda: OfflineNas(), lambda: FedAvgBaseline(key)):
        out = {}
        for fused in (False, True):
            out[fused] = FedEngine(
                api, clients,
                RunConfig(population=3, generations=1, seed=1, lr0=0.01,
                          backend="vmap", fused=fused),
                strategy=strat()).run()
        assert dataclasses.asdict(out[False].stats) == \
            dataclasses.asdict(out[True].stats)
        for a, b in zip(out[False].reports, out[True].reports):
            if a.objs is not None:
                np.testing.assert_array_equal(a.objs, b.objs)
            assert a.best_err == b.best_err


def test_master_donation_gating(api):
    """Donation is only enabled when nothing re-reads the old master:
    lossy uplink codecs (CodecBackend re-reads it for the uplink delta)
    and CPU hosts (XLA cannot reuse the buffers) disable it."""
    from repro.engine.backends import VmapBackend, master_donation_safe
    assert master_donation_safe(RunConfig())
    assert master_donation_safe(RunConfig(downlink_codec="cast"))
    assert not master_donation_safe(RunConfig(uplink_codec="int8"))
    assert not master_donation_safe(RunConfig(uplink_codec="topk:0.25"))
    if jax.default_backend() == "cpu":
        backend = VmapBackend(api, tiny_clients(num_clients=4, n=240),
                              RunConfig())
        assert backend.donate_master is False


def test_test_batches_lru_refreshes_on_hit(api):
    """Size-2 test-stack cache is true LRU: a hit refreshes recency, so
    alternating participant sets never evict the entry just used."""
    from repro.engine.backends import VmapBackend
    clients = tiny_clients(num_clients=6, n=360)
    backend = VmapBackend(api, clients, RunConfig())
    a, b, c = np.array([0, 1]), np.array([2, 3]), np.array([4, 5])
    backend._test_batches(a)
    backend._test_batches(b)
    backend._test_batches(a)       # hit must refresh A's recency
    backend._test_batches(c)       # evicts B (least recently used), not A
    assert set(backend._test_cache) == {(0, 1), (4, 5)}


def test_round_report_round_s(rt_parity):
    """wall_s stays cumulative (documented); round_s is the per-round
    delta and both are surfaced in the history dict."""
    res = rt_parity["vmap"][0]
    walls = [r.wall_s for r in res.reports]
    rounds = [r.round_s for r in res.reports]
    assert all(w2 >= w1 for w1, w2 in zip(walls, walls[1:]))
    assert all(r >= 0 for r in rounds)
    assert sum(rounds) == pytest.approx(walls[-1], abs=1e-6)
    hist = res.history()
    assert hist["round_s"] == rounds and hist["wall_s"] == walls


MESH_8DEV_SCRIPT = """
import dataclasses
import jax
import numpy as np

assert len(jax.devices()) == 8, jax.devices()

from repro.configs import get_config
from repro.core import make_api
from repro.data import make_classification, make_clients, partition_iid
from repro.engine import FedEngine, RunConfig

api = make_api(get_config("cifar-supernet", smoke=True))
x, y = make_classification(0, 480, image=8, signal=1.5, noise=0.5)
clients = make_clients(x, y, partition_iid(0, 480, 8),
                       batch=20, test_batch=20)
out = {}
for bk in ("vmap", "mesh"):
    eng = FedEngine(api, clients,
                    RunConfig(population=4, generations=2, seed=0,
                              lr0=0.01, backend=bk))
    out[bk] = eng.run()
    # fused (default): O(1) dispatches per generation — 2 train fills on
    # gen 1, 1 per later gen, 1 eval per gen — even on a real 8-way mesh
    assert eng.backend.dispatches == 2 * 2 + 1, (bk, eng.backend.dispatches)
    if bk == "mesh":
        assert eng.backend.num_devices == 8, eng.backend.num_devices
a, b = out["vmap"], out["mesh"]
assert dataclasses.asdict(a.stats) == dataclasses.asdict(b.stats)
for ra, rb in zip(a.reports, b.reports):
    np.testing.assert_allclose(ra.objs, rb.objs, atol=1e-5)
diff = max(float(np.abs(np.asarray(p) - np.asarray(q)).max())
           for p, q in zip(jax.tree.leaves(a.extras["final_master"]),
                           jax.tree.leaves(b.extras["final_master"])))
assert diff <= 1e-5, diff
print("OK", diff)
"""


@pytest.mark.slow
def test_mesh_parity_forced_8_devices():
    """Run the vmap/mesh parity check on a FORCED 8-device CPU mesh.

    XLA device count is fixed at first jax import, so an already-running
    single-device pytest process cannot grow a mesh — a fresh subprocess
    with XLA_FLAGS set is the only faithful way to test real sharding."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep \
        + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", MESH_8DEV_SCRIPT],
                          capture_output=True, text=True, timeout=900,
                          env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK" in proc.stdout


@pytest.mark.slow
def test_offline_backend_parity(api):
    clients = tiny_clients(num_clients=4, n=240)
    out = {}
    for bk in PARITY_BACKENDS:
        out[bk] = FedEngine(api, clients,
                            RunConfig(population=3, generations=1, seed=1,
                                      lr0=0.01, backend=bk),
                            strategy=OfflineNas()).run()
    for bk in ("vmap", "mesh"):
        np.testing.assert_allclose(out["loop"].reports[0].objs,
                                   out[bk].reports[0].objs, atol=1e-5)
        assert dataclasses.asdict(out["loop"].stats) == \
            dataclasses.asdict(out[bk].stats)


@pytest.mark.slow
def test_fedavg_baseline_backend_parity(api):
    clients = tiny_clients(num_clients=4, n=240)
    key = np.array([1, 0, 2, 3], np.int32)
    out = {}
    for bk in PARITY_BACKENDS:
        out[bk] = FedEngine(api, clients,
                            RunConfig(generations=2, seed=0, lr0=0.01,
                                      backend=bk),
                            strategy=FedAvgBaseline(key)).run()
    errs_l = [r.best_err for r in out["loop"].reports]
    for bk in ("vmap", "mesh"):
        assert max_leaf_diff(out["loop"].extras["params"],
                             out[bk].extras["params"]) <= 1e-5
        np.testing.assert_allclose(
            errs_l, [r.best_err for r in out[bk].reports], atol=1e-5)


# ---------------------------------------------------------------------------
# aggregate_backend routing (Algorithm 3 kernel selection)
# ---------------------------------------------------------------------------

def test_unknown_aggregate_backend_rejected_at_config_time():
    with pytest.raises(ValueError, match="aggregate_backend"):
        RunConfig(aggregate_backend="nope")


def test_unknown_execution_backend_rejected_at_config_time(api):
    with pytest.raises(ValueError, match="unknown execution backend"):
        FedEngine(api, tiny_clients(num_clients=4, n=240),
                  RunConfig(backend="warp"))


@pytest.mark.slow
@pytest.mark.parametrize("bk", ["loop", "vmap", "mesh"])
def test_pallas_aggregate_matches_xla(api, bk):
    """Every execution backend honors aggregate_backend='pallas'
    identically: same search, Algorithm 3 through the kernel."""
    clients = tiny_clients(num_clients=4, n=240)
    out = {}
    for agg in ("xla", "pallas"):
        out[agg] = FedEngine(api, clients,
                             RunConfig(population=2, generations=1, seed=0,
                                       lr0=0.01, backend=bk,
                                       aggregate_backend=agg)).run()
    assert max_leaf_diff(out["xla"].extras["final_master"],
                         out["pallas"].extras["final_master"]) <= 1e-5
    np.testing.assert_allclose(out["xla"].reports[0].objs,
                               out["pallas"].reports[0].objs, atol=1e-5)


def test_engine_run_is_reentrant(api):
    clients = tiny_clients(num_clients=4, n=240)
    eng = FedEngine(api, clients,
                    RunConfig(population=2, generations=1, seed=5),
                    strategy=OfflineNas())
    first = eng.run()
    passes = first.stats.client_train_passes
    second = eng.run()
    assert [r.gen for r in second.reports] == [1]
    assert second.stats.client_train_passes == passes
    np.testing.assert_array_equal(first.reports[0].objs,
                                  second.reports[0].objs)


# ---------------------------------------------------------------------------
# batched fill-aggregation vs the per-upload oracle
# ---------------------------------------------------------------------------

def test_fill_aggregate_stacked_matches_oracle(api):
    master = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    keys = [rng.integers(0, 4, api.num_blocks).astype(np.int32)
            for _ in range(3)]
    ups, weights = [], [2.0, 1.0, 0.5]
    for i, k in enumerate(keys):
        p = jax.tree.map(
            lambda x: x + 0.05 * (i + 1) * jnp.ones_like(x), master)
        ups.append(p)
    oracle = fill_aggregate(
        master, [(p, api.trained_mask(p, k), w)
                 for p, k, w in zip(ups, keys, weights)])
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ups)
    got = fill_aggregate_stacked(
        master, [(stacked, np.stack(keys),
                  np.asarray(weights, np.float32))],
        mask_fn=api.trained_mask)
    assert max_leaf_diff(oracle, got) <= 1e-5


def test_fill_aggregate_stacked_multi_chunk(api):
    master = api.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(11)
    keys = [rng.integers(0, 4, api.num_blocks).astype(np.int32)
            for _ in range(4)]
    ups = [jax.tree.map(lambda x: x + 0.1 * (i + 1) * jnp.ones_like(x),
                        master) for i in range(4)]
    weights = [1.0, 3.0, 2.0, 2.0]
    oracle = fill_aggregate(
        master, [(p, api.trained_mask(p, k), w)
                 for p, k, w in zip(ups, keys, weights)])
    chunks = []
    for sl in (slice(0, 2), slice(2, 4)):
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ups[sl])
        chunks.append((stacked, np.stack(keys[sl]),
                       np.asarray(weights[sl], np.float32)))
    got = fill_aggregate_stacked(master, chunks, mask_fn=api.trained_mask)
    assert max_leaf_diff(oracle, got) <= 1e-5


# ---------------------------------------------------------------------------
# evaluation-phase communication accounting (Section IV.G completeness)
# ---------------------------------------------------------------------------

def test_rt_eval_comm_accounted(api):
    clients = tiny_clients(num_clients=4, n=240)
    cfg = RunConfig(population=2, generations=1, seed=0)
    res = FedEngine(api, clients, cfg, strategy=RealTimeNas()).run()
    m, two_n = len(clients), 2 * cfg.population
    expect_down = (BYTES_PER_PARAM * api.master_params()
                   + api.key_bytes * two_n) * m
    expect_up = ERROR_COUNT_BYTES * two_n * m
    assert res.stats.eval_down_bytes == expect_down
    assert res.stats.eval_up_bytes == expect_up
    # eval traffic is included in the totals
    assert res.stats.down_bytes > res.stats.eval_down_bytes > 0
    assert res.stats.up_bytes > res.stats.eval_up_bytes > 0


def test_key_bytes_exposed(api):
    # 4 choice blocks x 2 bits = 1 byte on the wire
    assert api.key_bytes == (2 * api.num_blocks + 7) // 8


# ---------------------------------------------------------------------------
# ClientBatch stacking
# ---------------------------------------------------------------------------

def test_client_batch_stack_shapes():
    clients = tiny_clients(num_clients=4, n=240)
    cb = ClientBatch.stack(clients, split="train")
    assert cb.xb.shape[0] == 4 and cb.yb.shape[0] == 4
    assert cb.xb.shape[1:] == clients[0].train[0].shape
    np.testing.assert_array_equal(cb.client_ids, [0, 1, 2, 3])
    np.testing.assert_allclose(cb.weights,
                               [c.weight for c in clients])
    assert cb.samples_per_shard == (clients[0].train[0].shape[0]
                                    * clients[0].train[0].shape[1])


def test_client_batch_ragged_raises():
    a = tiny_clients(num_clients=4, n=240)
    b = tiny_clients(num_clients=2, n=480)   # different shard shapes
    with pytest.raises(ValueError):
        ClientBatch.stack([a[0], b[0]], split="train")


def test_shape_buckets_order_preserving():
    shapes = [(2, 5), (3, 5), (2, 5), (3, 5), (2, 5)]
    assert shape_buckets(shapes) == [[0, 2, 4], [1, 3]]


# ---------------------------------------------------------------------------
# legacy shims
# ---------------------------------------------------------------------------

def test_rt_enas_shim_matches_engine(api):
    clients = tiny_clients(num_clients=4, n=240)
    cfg = RunConfig(population=3, generations=2, seed=2, lr0=0.05)
    hist = rt_enas.run(api, clients, cfg)
    res = FedEngine(api, clients, cfg, strategy=RealTimeNas()).run()
    expect = res.history()
    assert hist["gen"] == [1, 2]
    for k in ("gen", "best_err", "knee_err", "down_gb", "up_gb",
              "train_passes"):
        assert hist[k] == expect[k], k
    assert set(hist) >= {"objs", "parent_keys", "best_key", "knee_key",
                         "wall_s", "final_master", "stats"}


def test_rt_enas_shim_callback(api):
    clients = tiny_clients(num_clients=4, n=240)
    seen = []
    hist = rt_enas.run(api, clients,
                       RunConfig(population=3, generations=2, seed=0),
                       callback=lambda gen, h: seen.append(
                           (gen, h["gen"][-1], len(h["gen"]), h)))
    assert [(g, last, n) for g, last, n, _ in seen] == [(1, 1, 1), (2, 2, 2)]
    # legacy contract: the callback dict IS the returned history, which
    # gains final_master/stats after the run completes
    assert seen[0][3] is hist
    assert "final_master" in hist and "stats" in hist


def test_offline_enas_shim_history_layout(api):
    clients = tiny_clients(num_clients=4, n=240)
    hist = offline_enas.run(
        api, clients, RunConfig(population=2, generations=1, seed=3))
    assert hist["gen"] == [1]
    assert "best_key" not in hist and "knee_err" not in hist
    assert np.isfinite(hist["best_err"]).all()
    assert hist["stats"].client_train_passes == 2 * 2 * len(clients)
