"""Fill-aggregation (Algorithm 3) semantics: faithful to the paper's
pseudo-code and equivalent between the XLA and Pallas backends."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.aggregate import (
    cnn_trained_mask, fedavg, fill_aggregate, supernet_trained_mask,
)
from repro.models import cnn
from repro.models import transformer as tr


@pytest.fixture(scope="module")
def cnn_setup():
    cfg = get_config("cifar-supernet", smoke=True)
    params = cnn.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def perturb(params, seed):
    leaves, treedef = jax.tree.flatten(params)
    rng = np.random.default_rng(seed)
    return jax.tree.unflatten(
        treedef, [l + jnp.asarray(rng.normal(size=l.shape) * 0.1, l.dtype)
                  for l in leaves])


def test_untrained_branch_keeps_master(cnn_setup):
    cfg, master = cnn_setup
    k1, k2 = np.array([1, 0, 2, 3]), np.array([2, 1, 3, 0])
    u1, u2 = perturb(master, 1), perturb(master, 2)
    agg = fill_aggregate(master, [(u1, cnn_trained_mask(u1, k1), 1.0),
                                  (u2, cnn_trained_mask(u2, k2), 1.0)])
    # block 0: branch 3 (sepconv) untouched by either client -> master kept
    np.testing.assert_allclose(
        np.asarray(agg["blocks"][0]["sepconv"]["pw1"]),
        np.asarray(master["blocks"][0]["sepconv"]["pw1"]), rtol=1e-6)


def test_single_trainer_fill_rule(cnn_setup):
    """Algorithm 3 line 12-14: trained branch averages the client value
    with the previous master weighted by the *other* clients' weights."""
    cfg, master = cnn_setup
    k1, k2 = np.array([1, 0, 2, 3]), np.array([2, 1, 3, 0])
    u1, u2 = perturb(master, 3), perturb(master, 4)
    agg = fill_aggregate(master, [(u1, cnn_trained_mask(u1, k1), 3.0),
                                  (u2, cnn_trained_mask(u2, k2), 1.0)])
    got = np.asarray(agg["blocks"][0]["residual"]["c1"])
    expect = (0.75 * np.asarray(u1["blocks"][0]["residual"]["c1"])
              + 0.25 * np.asarray(master["blocks"][0]["residual"]["c1"]))
    np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-6)


def test_non_choice_params_plain_fedavg(cnn_setup):
    cfg, master = cnn_setup
    k = np.array([0, 0, 0, 0])
    u1, u2 = perturb(master, 5), perturb(master, 6)
    agg = fill_aggregate(master, [(u1, cnn_trained_mask(u1, k), 1.0),
                                  (u2, cnn_trained_mask(u2, k), 1.0)])
    expect = 0.5 * np.asarray(u1["stem"]) + 0.5 * np.asarray(u2["stem"])
    np.testing.assert_allclose(np.asarray(agg["stem"]), expect, rtol=1e-5,
                               atol=1e-6)


def test_all_branches_trained_equals_fedavg(cnn_setup):
    cfg, master = cnn_setup
    ones_mask = jax.tree.map(lambda x: jnp.ones(()), master)
    u1, u2 = perturb(master, 7), perturb(master, 8)
    agg = fill_aggregate(master, [(u1, ones_mask, 2.0), (u2, ones_mask, 1.0)])
    avg = fedavg([(u1, 2.0), (u2, 1.0)])
    for a, b in zip(jax.tree.leaves(agg), jax.tree.leaves(avg)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)


def test_pallas_backend_matches_xla(cnn_setup):
    cfg, master = cnn_setup
    k1, k2 = np.array([1, 2, 3, 0]), np.array([3, 3, 1, 2])
    u1, u2 = perturb(master, 9), perturb(master, 10)
    ups = [(u1, cnn_trained_mask(u1, k1), 1.5),
           (u2, cnn_trained_mask(u2, k2), 0.5)]
    a_xla = fill_aggregate(master, ups, backend="xla")
    a_pl = fill_aggregate(master, ups, backend="pallas")
    for x, y in zip(jax.tree.leaves(a_xla), jax.tree.leaves(a_pl)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-4,
                                   atol=1e-5)


def test_supernet_mask_layout():
    cfg = get_config("qwen1.5-0.5b", smoke=True).replace(supernet=True)
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    key = np.array([0, 2], np.int32)   # layer0: identity, layer1: branch 2
    mask = supernet_trained_mask(params, key)
    m = np.asarray(mask["layers"]["attn"]["wq"]["w"])
    assert m.shape[:2] == (2, 3)
    assert m[0].sum() == 0          # identity trains nothing
    assert m[1, 1] == 1 and m[1, 0] == 0 and m[1, 2] == 0
    # non-layer params always trained
    assert np.asarray(mask["embed"]["table"]) == 1.0
