"""MoE dispatch invariants + shard_map/gather equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.moe import _capacity, _moe_apply_gather, moe_apply, moe_init

CFG = get_config("granite-moe-1b-a400m", smoke=True).replace(
    capacity_factor=8.0)   # ample capacity: nothing drops


@pytest.fixture(scope="module")
def setup():
    p = moe_init(jax.random.PRNGKey(0), CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, CFG.d_model)) * 0.5
    return p, x


def test_capacity_rounding():
    assert _capacity(100, 4, 2, 1.25) % 8 == 0
    assert _capacity(100, 4, 2, 1.25) >= 100 * 2 * 1.25 / 4


def test_output_finite_and_shaped(setup):
    p, x = setup
    y, aux = moe_apply(p, x, CFG)
    assert y.shape == x.shape
    assert not bool(jnp.isnan(y).any())
    assert float(aux) >= 1.0 - 1e-3   # >= 1 by Cauchy-Schwarz for top-k


def test_ample_capacity_every_token_processed(setup):
    """With gates renormalized and no drops, output != 0 for all tokens."""
    p, x = setup
    y, _ = moe_apply(p, x, CFG)
    norms = jnp.linalg.norm(y.reshape(-1, y.shape[-1]), axis=-1)
    assert float(norms.min()) > 0


def test_tight_capacity_drops_gracefully(setup):
    p, x = setup
    cfg = CFG.replace(capacity_factor=0.1)
    y, _ = moe_apply(p, x, cfg)
    assert not bool(jnp.isnan(y).any())


def test_permutation_equivariance(setup):
    """Routing is per-token: permuting tokens permutes outputs (with ample
    capacity so ranking order cannot change drop behaviour)."""
    p, x = setup
    y, _ = moe_apply(p, x, CFG)
    perm = jnp.array([1, 0])
    y_p, _ = moe_apply(p, x[perm], CFG)
    np.testing.assert_allclose(np.asarray(y[perm]), np.asarray(y_p),
                               rtol=2e-4, atol=2e-5)


def test_shard_map_matches_gather_on_trivial_mesh(setup):
    """On a (1, 1) mesh the shard_map path must equal the gather path."""
    p, x = setup
    from repro.launch import policy
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    y_ref, aux_ref = _moe_apply_gather(p, x, CFG)
    policy.set_mesh(mesh)
    try:
        with mesh:
            y_sm, aux_sm = jax.jit(
                lambda p_, x_: moe_apply(p_, x_, CFG))(p, x)
    finally:
        policy.set_mesh(None)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_sm),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(aux_ref), float(aux_sm), rtol=1e-4)
