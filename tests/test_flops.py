"""Analytic FLOPs/params counters: paper-table consistency + invariants."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # degrade to fixed-seed examples
    from _hyp_fallback import given, settings, strategies as st

from repro.configs import get_config
from repro.core import flops


def test_cnn_identity_cheapest_sepconv_vs_residual():
    k_id = np.zeros(12, dtype=int)
    k_res = np.ones(12, dtype=int)
    k_sep = np.full(12, 3)
    m_id = flops.cnn_subnet_macs(k_id)
    m_res = flops.cnn_subnet_macs(k_res)
    m_sep = flops.cnn_subnet_macs(k_sep)
    assert m_id < m_sep < m_res     # depthwise ~8-9x cheaper than conv


def test_cnn_macs_magnitude_matches_paper_scale():
    """Paper Table IV: evolved models are 0.03-0.4 GMAC; the all-residual
    master path should land in the same order as ResNet18 (0.5587 G)."""
    m = flops.cnn_subnet_macs(np.ones(12, dtype=int))
    assert 0.1e9 < m < 1.5e9


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 3), min_size=12, max_size=12),
       st.integers(0, 11))
def test_cnn_macs_monotone_in_branch_upgrade(key, pos):
    """Replacing identity by any parameterized branch never lowers MACs."""
    key = np.asarray(key)
    base = key.copy()
    base[pos] = 0
    up = key.copy()
    up[pos] = 1
    assert flops.cnn_subnet_macs(base) <= flops.cnn_subnet_macs(up)


def test_model_params_match_model_names():
    approx = {
        "qwen1.5-0.5b": 0.62e9, "mamba2-780m": 0.78e9,
        "starcoder2-3b": 3.1e9, "chatglm3-6b": 6.2e9,
        "deepseek-67b": 67e9, "zamba2-2.7b": 2.7e9,
    }
    for arch, expect in approx.items():
        got = flops.model_params(get_config(arch))
        assert 0.55 * expect < got < 1.6 * expect, (arch, got, expect)


def test_moe_active_params_smaller():
    cfg = get_config("llama4-scout-17b-a16e")
    total = flops.model_params(cfg)
    active = flops.model_params(cfg, active_only=True)
    assert active < total
    assert total > 15e9          # "17B" total
    # top-1 of 16 experts + shared => far fewer active
    assert active < 0.35 * total


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 3), min_size=24, max_size=24))
def test_subnet_params_bounded_by_full(key):
    cfg = get_config("qwen1.5-0.5b")
    key = np.asarray(key)
    sub = flops.subnet_params(cfg, key)
    full = flops.subnet_params(cfg, np.ones(24, dtype=int))
    assert sub <= flops.model_params(cfg)
    assert flops.subnet_params(cfg, np.zeros(24, dtype=int)) <= sub or \
        key.min() == 0
    assert sub <= full or key.max() > 1


def test_train_flops_is_6nd():
    cfg = get_config("qwen1.5-0.5b")
    n = flops.model_params(cfg, active_only=True)
    assert flops.train_flops(cfg, 1000) == pytest.approx(6.0 * n * 1000)
