"""NSGA-II unit + property tests (hypothesis)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # degrade to fixed-seed examples
    from _hyp_fallback import given, settings, strategies as st

from repro.core import nsga2

objs_strategy = st.integers(3, 24).flatmap(
    lambda n: st.lists(
        st.tuples(st.floats(0, 1, allow_nan=False),
                  st.floats(0, 1, allow_nan=False)),
        min_size=n, max_size=n))


def brute_force_front(objs):
    n = len(objs)
    return sorted(i for i in range(n)
                  if not any(nsga2.dominates(objs[j], objs[i])
                             for j in range(n) if j != i))


def test_dominates_basic():
    assert nsga2.dominates(np.array([0.1, 1.0]), np.array([0.2, 1.0]))
    assert not nsga2.dominates(np.array([0.1, 2.0]), np.array([0.2, 1.0]))
    assert not nsga2.dominates(np.array([0.1, 1.0]), np.array([0.1, 1.0]))


@settings(max_examples=50, deadline=None)
@given(objs_strategy)
def test_first_front_matches_brute_force(vals):
    objs = np.asarray(vals)
    fronts = nsga2.fast_non_dominated_sort(objs)
    assert sorted(fronts[0]) == brute_force_front(objs)


@settings(max_examples=50, deadline=None)
@given(objs_strategy)
def test_fronts_partition_population(vals):
    objs = np.asarray(vals)
    fronts = nsga2.fast_non_dominated_sort(objs)
    flat = [i for f in fronts for i in f]
    assert sorted(flat) == list(range(len(objs)))


@settings(max_examples=50, deadline=None)
@given(objs_strategy)
def test_no_intra_front_domination(vals):
    objs = np.asarray(vals)
    for front in nsga2.fast_non_dominated_sort(objs):
        for i in front:
            for j in front:
                assert not nsga2.dominates(objs[i], objs[j])


@settings(max_examples=50, deadline=None)
@given(objs_strategy, st.integers(1, 10))
def test_select_size_and_elitism(vals, n_sel):
    objs = np.asarray(vals)
    n_sel = min(n_sel, len(objs))
    sel = nsga2.select(objs, n_sel)
    assert len(sel) == n_sel and len(set(sel)) == n_sel
    # every first-front member not selected implies the front overflowed
    front0 = set(nsga2.fast_non_dominated_sort(objs)[0])
    if len(front0) <= n_sel:
        assert front0 <= set(sel)


def test_crowding_extremes_infinite():
    objs = np.array([[0.0, 1.0], [0.5, 0.5], [1.0, 0.0], [0.9, 0.1]])
    dist = nsga2.crowding_distance(objs, [0, 1, 2, 3])
    assert np.isinf(dist[0]) and np.isinf(dist[2])
    assert np.isfinite(dist[1]) and np.isfinite(dist[3])


def test_knee_point_picks_bulge():
    # convex front: knee should be the middle bulge point
    front = [0, 1, 2]
    objs = np.array([[0.0, 1.0], [0.1, 0.1], [1.0, 0.0]])
    assert nsga2.knee_point(objs, front) == 1
