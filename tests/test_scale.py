"""Million-client scale pins (ISSUE 7).

Three guarantees keep ``num_clients`` a cheap axis, and this file pins
each one:

  * **sampled-only materialization** — the ``StackedClientBase`` train
    store stacks only the round's sampled clients (size-2 true LRU,
    like the test-stack cache), so device memory tracks participation x
    population, never fleet size; a lazy ``ClientFleet`` additionally
    leaves unsampled clients unbuilt on the host.
  * **lazy-vs-eager parity** — at the paper-scale 16-client point the
    lazy path (index-space partition + ``ClientFleet``) reproduces the
    eager seed behavior exactly: byte-identical CommStats (logical,
    wire AND wasted-download ledgers) on every backend, fused and
    non-fused, and masters within 1e-5 across backends (bitwise within
    a backend).
  * **compact availability state** — ``availability_dist`` draws
    per-client check-in probabilities from counter-based streams, so
    the simulator holds O(1) state for any fleet size, deterministically
    per client.

The full 10^2 -> 10^6 sweep itself runs under ``-m slow``
(``test_scale_sweep_flat_to_a_million_clients``); the fast lane covers
the same machinery at 10^3.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import make_api
from repro.data import (
    ClientFleet, VirtualClassification, make_classification, make_clients,
    make_fleet, partition_iid,
)
from repro.engine import ClientSimConfig, ClientSimulator, FedEngine, \
    RunConfig

PARITY_BACKENDS = ("loop", "vmap", "mesh")


@pytest.fixture(scope="module")
def api():
    return make_api(get_config("cifar-supernet", smoke=True))


def eager_16(seed=0):
    x, y = make_classification(seed, 960, image=8, signal=1.5, noise=0.5)
    part = partition_iid(seed, 960, 16)
    return x, y, part


# ---------------------------------------------------------------------------
# sampled-only train store
# ---------------------------------------------------------------------------

def test_train_store_lru_evicts_and_refreshes_on_hit(api):
    """The sampled-client train store mirrors the test-stack cache: a
    size-2 true LRU keyed by the sorted participant tuple, where a hit
    refreshes recency (so alternating rounds never thrash)."""
    from repro.engine.backends import VmapBackend
    x, y, part = eager_16()
    clients = make_clients(x, y, part, batch=20, test_batch=20)
    backend = VmapBackend(api, clients, RunConfig())
    a, b, c = [0, 1, 2], [3, 4], [5, 6, 7]
    sa = backend._train_store(a)
    backend._train_store(b)
    assert backend._train_store(a) is sa       # hit: same stacked arrays
    backend._train_store(c)                    # evicts b (LRU), not a
    assert set(backend._train_cache) == {(0, 1, 2), (5, 6, 7)}
    assert backend._train_store(a) is sa       # survived the eviction
    # unordered / duplicated ids canonicalize to the same key
    assert backend._train_store([2, 0, 1, 1]) is sa


def test_train_store_stacks_only_sampled_clients(api):
    """Stack height equals the sampled-client count — device memory from
    stacking tracks participation, not fleet size."""
    from repro.engine.backends import VmapBackend
    x, y, part = eager_16()
    clients = make_clients(x, y, part, batch=20, test_batch=20)
    backend = VmapBackend(api, clients, RunConfig())
    store = backend._train_store([3, 7, 11])
    rows = sum(xb.shape[0] for _, xb, yb in store)
    assert rows == 3
    assert sorted(cid for pos, _, _ in store for cid in pos) == [3, 7, 11]


@pytest.mark.parametrize("bk", PARITY_BACKENDS)
def test_fleet_materialization_tracks_participation(api, bk):
    """A 400-client lazy fleet at 16/400 participation: every backend
    touches only the sampled clients, fleet-size-many never exist."""
    k, spc = 400, 30
    src = VirtualClassification(2, k * spc, image=8, signal=1.5, noise=0.5)
    fleet = ClientFleet(src, partition_iid(2, k * spc, k), batch=5,
                        test_batch=5, cache_size=64)
    eng = FedEngine(api, fleet,
                    RunConfig(population=4, generations=2, seed=0,
                              participation=16 / k, backend=bk))
    res = eng.run()
    assert res.reports[-1].best_err is not None
    # <= sampled-per-round x rounds ever built; far below the fleet
    assert 16 <= fleet.materialized <= 16 * 2
    assert fleet.cached <= fleet.cache_size < k


def test_train_cache_turns_over_across_rounds(api):
    """Across rounds with different participant sets the LRU holds the
    two most recent rounds' stacks and evicts older ones."""
    from repro.engine.backends import VmapBackend
    x, y, part = eager_16()
    fleet = make_fleet(x, y, part, batch=20, test_batch=20)
    eng = FedEngine(api, fleet,
                    RunConfig(population=4, generations=3, seed=0,
                              participation=0.25, backend="vmap"))
    keys = []

    def snap(gen, report):
        keys.append(list(eng.backend._train_cache))

    eng.run(callback=snap)
    assert all(len(ks) <= 2 for ks in keys)
    assert all(len(k) == 4 for ks in keys for k in ks)   # 4 sampled/round


# ---------------------------------------------------------------------------
# lazy-vs-eager parity pin (the 16-client paper-scale point)
# ---------------------------------------------------------------------------

PARITY_VARIANTS = (("loop", True), ("vmap", True), ("vmap", False),
                   ("mesh", True), ("mesh", False))


@pytest.fixture(scope="module")
def lazy_eager_parity(api):
    """The same dropout search (so the wasted-download ledger is live)
    through the eager seed path and the lazy fleet, on every backend
    variant.  Codec-free: int8 quantization would let a one-quantum
    bucket flip amplify benign cross-backend float noise past the 1e-5
    master bar — the wire ledger gets its own bitwise eager-vs-lazy pin
    in ``test_lazy_path_bitwise_with_int8_uplink``."""
    x, y, part = eager_16()
    eager = make_clients(x, y, part.materialize(), batch=20, test_batch=20)

    def run(clients, backend, fused):
        return FedEngine(
            api, clients,
            RunConfig(population=4, generations=2, seed=0, lr0=0.01,
                      backend=backend, fused=fused,
                      client_sim={"availability": 0.9, "dropout": 0.25,
                                  "seed": 3})).run()

    out = {}
    for backend, fused in PARITY_VARIANTS:
        lazy = make_fleet(x, y, part, batch=20, test_batch=20)
        out[(backend, fused)] = (run(eager, backend, fused),
                                 run(lazy, backend, fused))
    return out


@pytest.mark.parametrize("variant", PARITY_VARIANTS,
                         ids=[f"{b}-{'fused' if f else 'nofused'}"
                              for b, f in PARITY_VARIANTS])
def test_lazy_path_bitwise_equals_eager_per_variant(lazy_eager_parity,
                                                    variant):
    """Within one backend variant the lazy fleet is BITWISE the eager
    run: identical report trajectories, identical masters, and
    byte-identical CommStats including the wasted-download ledger."""
    res_e, res_l = lazy_eager_parity[variant]
    assert dataclasses.asdict(res_e.stats) == dataclasses.asdict(res_l.stats)
    assert res_e.stats.wasted_down_bytes > 0     # dropout: ledger is live
    for re_, rl in zip(res_e.reports, res_l.reports):
        np.testing.assert_array_equal(re_.objs, rl.objs)
        assert re_.best_err == rl.best_err
        assert re_.n_survivors == rl.n_survivors
    for p, q in zip(jax.tree.leaves(res_e.extras["final_master"]),
                    jax.tree.leaves(res_l.extras["final_master"])):
        np.testing.assert_array_equal(np.asarray(p), np.asarray(q))


def test_lazy_path_bitwise_with_int8_uplink(api):
    """The wire ledger under a lossy codec: eager vs lazy stays BITWISE
    identical (same backend), with wire bytes below logical and the
    wasted ledger counting wire bytes."""
    x, y, part = eager_16()

    def run(clients):
        return FedEngine(
            api, clients,
            RunConfig(population=4, generations=2, seed=0, lr0=0.01,
                      backend="vmap", uplink_codec="int8",
                      client_sim={"availability": 0.9, "dropout": 0.25,
                                  "seed": 3})).run()

    res_e = run(make_clients(x, y, part.materialize(), batch=20,
                             test_batch=20))
    res_l = run(make_fleet(x, y, part, batch=20, test_batch=20))
    assert dataclasses.asdict(res_e.stats) == dataclasses.asdict(res_l.stats)
    assert res_e.stats.up_wire_bytes < res_e.stats.up_bytes
    assert res_e.stats.wasted_down_bytes > 0
    for p, q in zip(jax.tree.leaves(res_e.extras["final_master"]),
                    jax.tree.leaves(res_l.extras["final_master"])):
        np.testing.assert_array_equal(np.asarray(p), np.asarray(q))


def test_lazy_parity_across_backends(lazy_eager_parity):
    """Across backend variants (lazy path): byte-identical CommStats
    everywhere, masters within 1e-5 of the loop reference."""
    ref = lazy_eager_parity[("loop", True)][1]
    for variant, (_, res) in lazy_eager_parity.items():
        assert dataclasses.asdict(res.stats) == \
            dataclasses.asdict(ref.stats), variant
        diff = max(float(np.abs(np.asarray(p) - np.asarray(q)).max())
                   for p, q in zip(
                       jax.tree.leaves(ref.extras["final_master"]),
                       jax.tree.leaves(res.extras["final_master"])))
        assert diff <= 1e-5, (variant, diff)
        for ra, rb in zip(ref.reports, res.reports):
            np.testing.assert_allclose(ra.objs, rb.objs, atol=1e-5)


# ---------------------------------------------------------------------------
# compact availability state
# ---------------------------------------------------------------------------

def test_availability_dist_is_deterministic_and_o1_state():
    cfg = ClientSimConfig(availability_dist=("uniform", 0.3, 0.9), seed=6)
    a = ClientSimulator(cfg, 10**6)
    b = ClientSimulator(cfg, 10**6)
    ids = np.asarray([0, 17, 999_999, 123_456])
    np.testing.assert_array_equal(a._avail_p(ids), b._avail_p(ids))
    assert a.speed is None                    # no O(num_clients) arrays
    p = a._avail_p(ids)
    assert np.all((p >= 0.3) & (p <= 0.9))
    # a different seed redraws every client's probability stream
    c = ClientSimulator(dataclasses.replace(cfg, seed=7), 10**6)
    assert not np.array_equal(c._avail_p(ids), p)


def test_availability_dist_bernoulli_splits_fleet():
    cfg = ClientSimConfig(availability_dist=("bernoulli", 0.5), seed=1)
    sim = ClientSimulator(cfg, 4000)
    p = sim._avail_p(np.arange(4000))
    assert set(np.unique(p)) <= {0.0, 1.0}
    assert 0.4 < p.mean() < 0.6
    # always-on clients survive every round, never-on clients none
    on = int(np.flatnonzero(p == 1.0)[0])
    off = int(np.flatnonzero(p == 0.0)[0])
    for _ in range(5):
        ctx = sim.draw_round(np.asarray([on, off]))
        assert on in ctx.survivors and off not in ctx.survivors


def test_availability_dist_activates_and_validates():
    assert ClientSimConfig(availability_dist=("beta", 2.0, 5.0)).is_active
    assert not ClientSimConfig().is_active
    with pytest.raises(ValueError, match="mutually exclusive"):
        ClientSimConfig(availability_dist=("bernoulli", 0.5),
                        availability_trace=(1.0, 1.0))
    for bad in [("bernoulli", 1.5), ("uniform", 0.9, 0.1),
                ("beta", 0.0, 1.0), ("zipf", 1.0), ("bernoulli",)]:
        with pytest.raises(ValueError):
            ClientSimConfig(availability_dist=bad)


def test_availability_dist_runs_through_engine(api):
    """End to end on a lazy fleet: a Bernoulli(0.6) fleet split loses
    clients without disturbing determinism (two identical runs agree)."""
    x, y, part = eager_16()
    outs = []
    for _ in range(2):
        fleet = make_fleet(x, y, part, batch=20, test_batch=20)
        res = FedEngine(
            api, fleet,
            RunConfig(population=4, generations=2, seed=0,
                      backend="vmap",
                      client_sim={"availability_dist": ("bernoulli", 0.6),
                                  "seed": 5})).run()
        outs.append(res)
    a, b = outs
    assert dataclasses.asdict(a.stats) == dataclasses.asdict(b.stats)
    assert [r.n_survivors for r in a.reports] == \
        [r.n_survivors for r in b.reports]
    assert any(r.n_survivors < r.n_sampled for r in a.reports)


# ---------------------------------------------------------------------------
# the sweep itself
# ---------------------------------------------------------------------------

def _fed_nas():
    import importlib
    import os
    import sys
    bench = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                         "..", "benchmarks"))
    if bench not in sys.path:
        sys.path.insert(0, bench)
    return importlib.import_module("fed_nas")


def test_scale_sweep_smoke_small():
    """10^2 -> 10^3 legs of the benchmark sweep complete with flat peak
    bytes and fixed per-round participation (the CI smoke leg runs the
    same code path via --mode scale)."""
    fed_nas = _fed_nas()
    rep = fed_nas.scale_sweep(client_counts=(100, 1000), sampled=8,
                              generations=2, population=4)
    pts = rep["points"]
    assert set(pts) == {"100", "1000"}
    for r in pts.values():
        assert r["clients_materialized"] <= 8 * 2
        assert r["peak_live_bytes"] > 0
    assert rep["summary"]["peak_live_ratio"] < 2.0


@pytest.mark.slow
def test_scale_sweep_flat_to_a_million_clients():
    """The acceptance sweep: 10^2 -> 10^6 clients at 16 participants per
    round, per-round wall time and peak live bytes flat within 2x."""
    fed_nas = _fed_nas()
    rep = fed_nas.scale_sweep(
        client_counts=(100, 10_000, 1_000_000), sampled=16,
        generations=3, population=6)
    s = rep["summary"]
    assert s["flat_within_2x"], s
    big = rep["points"]["1000000"]
    assert big["clients_materialized"] <= 16 * 3
    assert big["partition_host_bytes"] < 100e6     # perm + cuts only
