"""The paper's technique applied to an assigned transformer architecture:
real-time federated NAS over a qwen-family LM supernet (DESIGN.md §3's
beyond-paper extension), end to end on CPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import make_api, rt_enas
from repro.core.supernet import lm_supernet_api
from repro.data import make_lm_stream
from repro.data.pipeline import ClientDataset


def lm_clients(cfg, num_clients=4, seqs=96, seq_len=32):
    x, y = make_lm_stream(0, seqs, seq_len, cfg.vocab_size)
    shard = seqs // num_clients
    return [ClientDataset(i, x[i * shard:(i + 1) * shard],
                          y[i * shard:(i + 1) * shard],
                          batch=8, test_batch=8)
            for i in range(num_clients)]


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen1.5-0.5b", smoke=True).replace(
        supernet=True, d_model=64, d_ff=128, vocab_size=128, num_heads=4,
        num_kv_heads=4)
    api = lm_supernet_api(cfg)
    return cfg, api, lm_clients(cfg)


def test_lm_supernet_rt_nas_runs(setup):
    cfg, api, clients = setup
    rc = rt_enas.RunConfig(population=4, generations=2, seed=0)
    hist = rt_enas.run(api, clients, rc)
    assert hist["gen"] == [1, 2]
    objs = hist["objs"][-1]
    assert objs.shape == (8, 2)
    assert np.isfinite(objs).all()
    # FLOPs objective spreads across subnets (not all identical)
    assert len(np.unique(objs[:, 1])) > 1
    # the paper's efficiency invariant holds for LMs too
    m = len(clients)
    assert hist["train_passes"][-1] - hist["train_passes"][0] == m


def test_lm_payload_scales_with_key(setup):
    cfg, api, _ = setup
    full = api.payload_params(np.ones(cfg.num_layers, dtype=int))
    skip = api.payload_params(np.zeros(cfg.num_layers, dtype=int))
    lite = api.payload_params(np.full(cfg.num_layers, 3))
    assert skip < lite < full
    assert api.flops(np.zeros(cfg.num_layers, dtype=int)) < \
        api.flops(np.ones(cfg.num_layers, dtype=int))


def test_lm_supernet_masks_affect_loss(setup):
    cfg, api, clients = setup
    params = api.init(jax.random.PRNGKey(0))
    xb, yb = clients[0].train
    batch = {"x": xb[0], "y": yb[0]}
    losses = {b: float(api.loss(params, batch,
                                jnp.full((cfg.num_layers,), b, jnp.int32)))
              for b in range(4)}
    assert len({round(v, 6) for v in losses.values()}) == 4  # all distinct
    for v in losses.values():
        assert np.isfinite(v)
