"""repro.comm: codec roundtrip bounds (hypothesis), error-feedback
telescoping, wire-byte accounting, RunConfig validation, and per-codec
backend parity (loop == vmap == mesh CommStats and masters).

The parity block is the codec leg of the engine parity suite — CI also
runs this file under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
so the mesh backend shards over a real 8-way mesh with ``int8`` uplink.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # degrade to fixed-seed examples
    from _hyp_fallback import given, settings, strategies as st

from repro.comm import (
    CastCodec, ErrorFeedback, Int8Codec, PayloadCodec, TopKCodec,
    make_codec,
)
from repro.comm.sparsify import leaf_k
from repro.configs import get_config
from repro.core import make_api
from repro.data import make_classification, make_clients, partition_iid
from repro.engine import FedEngine, RunConfig

# strategy: small non-degenerate float vectors (bounded away from the
# fp16 overflow range; codecs are scale-relative so magnitude is free)
vectors = st.lists(
    st.floats(min_value=-100.0, max_value=100.0), min_size=1, max_size=64,
).map(lambda l: np.asarray(l, np.float32))


def _max_abs(x):
    return float(np.max(np.abs(np.asarray(x, np.float32))))


# ---------------------------------------------------------------------------
# codec spec parsing / validation
# ---------------------------------------------------------------------------

def test_make_codec_specs():
    assert isinstance(make_codec("none"), PayloadCodec)
    assert make_codec("none").is_identity
    assert make_codec("cast") == CastCodec(dtype="bf16")
    assert make_codec("cast:fp16") == CastCodec(dtype="fp16")
    assert make_codec("int8") == Int8Codec(backend="xla")
    assert make_codec("int8:pallas") == Int8Codec(backend="pallas")
    assert make_codec("topk") == TopKCodec(ratio=0.1)
    assert make_codec("topk:0.25") == TopKCodec(ratio=0.25)
    for codec in ("cast", "int8", "topk"):
        assert not make_codec(codec).is_identity


@pytest.mark.parametrize("bad", [
    "zip", "cast:f8", "int8:gpu", "topk:0", "topk:2.0", "topk:x", ""])
def test_make_codec_rejects_unknown(bad):
    with pytest.raises(ValueError):
        make_codec(bad)


def test_wire_bytes_per_codec():
    n = 10_000
    assert make_codec("none").wire_bytes(n) == 4 * n
    assert make_codec("cast").wire_bytes(n) == 2 * n
    assert make_codec("int8").wire_bytes(n) == n + 4
    # topk: 8 bytes per kept (index, value) entry
    assert make_codec("topk:0.1").wire_bytes(n) == 8 * (n // 10)
    assert make_codec("topk:1.0").wire_bytes(n) == 8 * n


# ---------------------------------------------------------------------------
# roundtrip bounds (property tests)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(vectors)
def test_cast_roundtrip_bound(x):
    """bf16 keeps 8 mantissa bits: relative error <= 2^-8 elementwise."""
    rt = np.asarray(make_codec("cast").roundtrip(jnp.asarray(x)))
    assert np.all(np.abs(rt - x) <= np.abs(x) * 2.0 ** -8 + 1e-30)


@settings(max_examples=25, deadline=None)
@given(vectors)
def test_int8_roundtrip_bound(x):
    """Symmetric int8: error <= scale/2 = max|x|/254 elementwise."""
    for spec in ("int8", "int8:pallas"):
        rt = np.asarray(make_codec(spec).roundtrip(jnp.asarray(x)))
        bound = _max_abs(x) / 254.0 + 1e-6
        assert np.all(np.abs(rt - x) <= bound), spec


def test_int8_pallas_matches_xla_route():
    rng = np.random.default_rng(0)
    tree = {"a": jnp.asarray(rng.normal(size=(513,)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(8, 33)), jnp.float32)}
    a = make_codec("int8").roundtrip(tree)
    b = make_codec("int8:pallas").roundtrip(tree)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@settings(max_examples=25, deadline=None)
@given(vectors, st.floats(min_value=0.05, max_value=1.0))
def test_topk_exact_k_sparsity(x, ratio):
    """Exactly k = max(1, round(ratio*n)) surviving entries (inputs are
    a.s. nonzero), and they are the k largest magnitudes."""
    x = (x + np.where(x >= 0, 1e-3, -1e-3)).astype(np.float32)  # nonzero
    k = leaf_k(x.size, ratio)
    rt = np.asarray(make_codec(f"topk:{ratio}").roundtrip(jnp.asarray(x)))
    kept = np.nonzero(rt)[0]
    assert len(kept) == k
    np.testing.assert_array_equal(rt[kept], x[kept])
    # no dropped entry is strictly larger than a kept one
    dropped = np.setdiff1d(np.arange(x.size), kept)
    if dropped.size:
        assert np.abs(x[dropped]).max() <= np.abs(x[kept]).min() + 1e-12


def test_codecs_pass_integer_leaves_through():
    tree = {"w": jnp.ones((16,), jnp.float32),
            "step": jnp.asarray([3], jnp.int32)}
    for spec in ("cast", "int8", "topk:0.5"):
        rt = make_codec(spec).roundtrip(tree)
        np.testing.assert_array_equal(np.asarray(rt["step"]), [3])


# ---------------------------------------------------------------------------
# error feedback: bias telescopes to the final residual
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["topk:0.1", "int8", "cast"])
def test_error_feedback_telescopes(spec):
    """sum_t sent_t == sum_t delta_t - residual_T exactly: the cumulative
    bias is one single-step compression error, not O(T) of them."""
    rng = np.random.default_rng(4)
    ef = ErrorFeedback(make_codec(spec))
    shape = (257,)
    true_sum = np.zeros(shape, np.float32)
    sent_sum = np.zeros(shape, np.float32)
    for _ in range(30):
        delta = jnp.asarray(rng.normal(size=shape) * 0.1, jnp.float32)
        sent = ef.step({"w": delta})["w"]
        true_sum += np.asarray(delta)
        sent_sum += np.asarray(sent)
    resid = np.asarray(ef.residual["w"])
    # the whole cumulative bias is exactly the final residual — one
    # (bounded) compression error, however many rounds ran
    np.testing.assert_allclose(true_sum - sent_sum, resid, atol=1e-4)


def test_error_feedback_beats_plain_topk_bias():
    """Same constant update stream: with EF the accumulated master tracks
    the true sum; without EF top-k never updates the dropped coords."""
    rng = np.random.default_rng(5)
    delta = jnp.asarray(rng.normal(size=(64,)) * 0.1, jnp.float32)
    codec = make_codec("topk:0.25")
    ef = ErrorFeedback(codec)
    with_ef = np.zeros(64, np.float32)
    without = np.zeros(64, np.float32)
    for _ in range(16):
        with_ef += np.asarray(ef.step({"w": delta})["w"])
        without += np.asarray(codec.roundtrip({"w": delta})["w"])
    true = 16 * np.asarray(delta)
    assert _max_abs(with_ef - true) < 0.5 * _max_abs(without - true)


def test_error_feedback_identity_codec_is_exact():
    ef = ErrorFeedback(make_codec("none"))
    d = {"w": jnp.arange(4, dtype=jnp.float32)}
    out = ef.step(d)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(d["w"]))
    assert ef.residual is None


# ---------------------------------------------------------------------------
# RunConfig validation (codecs + the numeric knobs)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    dict(uplink_codec="zip"), dict(downlink_codec="cast:f8"),
    dict(participation=0.0), dict(participation=-0.2),
    dict(participation=1.5), dict(population=1), dict(population=0),
    dict(lr0=-0.1), dict(local_epochs=-1),
])
def test_run_config_rejected_at_config_time(kw):
    with pytest.raises(ValueError):
        RunConfig(**kw)


def test_run_config_accepts_codecs():
    cfg = RunConfig(uplink_codec="int8", downlink_codec="topk:0.5")
    assert cfg.uplink_codec == "int8"
    assert RunConfig(participation=1.0).participation == 1.0


# ---------------------------------------------------------------------------
# engine wiring: per-codec backend parity + wire-byte accounting
# ---------------------------------------------------------------------------

def tiny_clients(num_clients=4, n=240, seed=0):
    x, y = make_classification(seed, n, image=8, signal=1.5, noise=0.5)
    return make_clients(x, y, partition_iid(seed, n, num_clients),
                        batch=20, test_batch=20)


@pytest.fixture(scope="module")
def api():
    return make_api(get_config("cifar-supernet", smoke=True))


def _run(api, clients, bk, up, down, gens=2):
    eng = FedEngine(api, clients,
                    RunConfig(population=4, generations=gens, seed=0,
                              lr0=0.01, backend=bk, uplink_codec=up,
                              downlink_codec=down))
    return eng.run()


@pytest.fixture(scope="module")
def codec_parity(api):
    clients = tiny_clients()
    out = {}
    for up, down in (("int8", "none"), ("topk:0.25", "cast")):
        out[(up, down)] = {bk: _run(api, clients, bk, up, down)
                           for bk in ("loop", "vmap", "mesh")}
    return out


@pytest.mark.slow
@pytest.mark.parametrize("pair", [("int8", "none"), ("topk:0.25", "cast")])
@pytest.mark.parametrize("bk", ["vmap", "mesh"])
def test_codec_backend_parity(codec_parity, pair, bk):
    """Same seed + codec: every backend reports byte-identical CommStats
    (wire AND logical ledgers) and masters within 1e-5."""
    ref, other = codec_parity[pair]["loop"], codec_parity[pair][bk]
    assert dataclasses.asdict(ref.stats) == dataclasses.asdict(other.stats)
    diff = max(float(jnp.abs(jnp.asarray(p) - jnp.asarray(q)).max())
               for p, q in zip(jax.tree.leaves(ref.extras["final_master"]),
                               jax.tree.leaves(other.extras["final_master"])))
    assert diff <= 1e-5
    for a, b in zip(ref.reports, other.reports):
        np.testing.assert_allclose(a.objs, b.objs, atol=1e-5)


@pytest.mark.slow
@pytest.mark.parametrize("codec", ["cast", "int8", "topk:0.25"])
def test_fused_nonfused_codec_parity(api, codec):
    """Each codec layered on top of the fused path reproduces the
    non-fused run exactly: the codec transform is a deterministic
    function of the (bitwise-identical) aggregates, so CommStats, per-
    generation errors and masters all match."""
    clients = tiny_clients()
    out = {}
    for fused in (False, True):
        eng = FedEngine(api, clients,
                        RunConfig(population=4, generations=2, seed=0,
                                  lr0=0.01, backend="vmap", fused=fused,
                                  uplink_codec=codec, downlink_codec=codec))
        out[fused] = eng.run()
    assert dataclasses.asdict(out[False].stats) == \
        dataclasses.asdict(out[True].stats)
    for a, b in zip(out[False].reports, out[True].reports):
        np.testing.assert_allclose(a.objs, b.objs, atol=1e-6)
    diff = max(float(jnp.abs(jnp.asarray(p) - jnp.asarray(q)).max())
               for p, q in zip(
                   jax.tree.leaves(out[False].extras["final_master"]),
                   jax.tree.leaves(out[True].extras["final_master"])))
    assert diff <= 1e-6


def test_int8_wire_reduction(api):
    """int8 on both directions cuts down+up wire bytes >= 3.5x vs fp32
    (keys and error counts stay uncompressed, so < 4.0x exactly)."""
    clients = tiny_clients()
    none = _run(api, clients, "vmap", "none", "none", gens=1).stats
    int8 = _run(api, clients, "vmap", "int8", "int8", gens=1).stats
    # logical ledger is codec-independent
    assert none.down_bytes == int8.down_bytes
    assert none.up_bytes == int8.up_bytes
    ratio = ((none.down_wire_bytes + none.up_wire_bytes)
             / (int8.down_wire_bytes + int8.up_wire_bytes))
    assert ratio >= 3.5


def test_wire_defaults_to_logical_without_codecs(api):
    clients = tiny_clients()
    stats = _run(api, clients, "loop", "none", "none", gens=1).stats
    assert stats.down_wire_bytes == stats.down_bytes
    assert stats.up_wire_bytes == stats.up_bytes


def test_codec_run_is_reentrant(api):
    """EF residuals reset per run(): two runs of one engine match."""
    clients = tiny_clients()
    eng = FedEngine(api, clients,
                    RunConfig(population=4, generations=2, seed=0,
                              lr0=0.01, backend="vmap",
                              uplink_codec="topk:0.25"))
    first, second = eng.run(), eng.run()
    assert dataclasses.asdict(first.stats) == dataclasses.asdict(second.stats)
    for p, q in zip(jax.tree.leaves(first.extras["final_master"]),
                    jax.tree.leaves(second.extras["final_master"])):
        np.testing.assert_array_equal(np.asarray(p), np.asarray(q))


def test_offline_strategy_with_codec(api):
    """The codec wrapper covers the fedavg-population path too: the run
    completes and the wire ledger shows the compression."""
    from repro.engine import OfflineNas
    clients = tiny_clients()
    res = FedEngine(api, clients,
                    RunConfig(population=2, generations=1, seed=1,
                              lr0=0.01, backend="vmap",
                              uplink_codec="int8"),
                    strategy=OfflineNas()).run()
    assert np.isfinite(res.reports[0].objs).all()
    assert res.stats.up_wire_bytes < res.stats.up_bytes
    assert res.stats.down_wire_bytes == res.stats.down_bytes
