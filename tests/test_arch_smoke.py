"""Deliverable (f): per-assigned-architecture smoke tests.

Each instantiates the REDUCED variant of the same family (2 layers,
d_model <= 512, <= 4 experts) and runs one forward and one train step on
CPU, asserting output shapes and no NaNs.  Decode is exercised for every
decoder-bearing arch.  The FULL configs are exercised only via the
multi-pod dry-run (ShapeDtypeStruct; see launch/dryrun.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.train import init_opt, make_train_step
from repro.models import transformer as tr

BATCH, SEQ = 2, 64


def make_batch(cfg, rng):
    batch = {
        "tokens": jax.random.randint(rng, (BATCH, SEQ), 0, cfg.vocab_size,
                                     dtype=jnp.int32),
        "labels": jax.random.randint(rng, (BATCH, SEQ), 0, cfg.vocab_size,
                                     dtype=jnp.int32),
    }
    if cfg.family in ("vlm", "audio"):
        batch["prefix"] = jnp.ones((BATCH, cfg.num_prefix, cfg.d_model),
                                   jnp.float32) * 0.1
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = get_config(request.param, smoke=True)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    return request.param, cfg, params


def test_forward_shapes_and_finite(arch_setup):
    arch, cfg, params = arch_setup
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits, aux, _ = tr.forward(params, cfg, batch["tokens"],
                                prefix=batch.get("prefix"))
    assert logits.shape == (BATCH, SEQ, cfg.vocab_size), arch
    assert not bool(jnp.isnan(logits).any()), arch
    assert np.isfinite(float(aux)), arch


def test_train_step_updates_and_finite(arch_setup):
    arch, cfg, params = arch_setup
    batch = make_batch(cfg, jax.random.PRNGKey(2))
    step = jax.jit(make_train_step(cfg, optimizer="sgd", lr=0.01,
                                   remat=False, fused_ce=True))
    opt = init_opt(params)
    new_params, _, loss = step(params, opt, batch)
    assert np.isfinite(float(loss)), arch
    # at least one parameter moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved, arch


def test_decode_step_finite(arch_setup):
    arch, cfg, params = arch_setup
    enc_len = cfg.num_prefix if cfg.family == "audio" else 0
    cache = tr.init_cache(params, cfg, BATCH, 32, enc_len=enc_len)
    tok = jnp.zeros((BATCH, 1), jnp.int32)
    logits, cache = tr.decode_step(params, cfg, tok, cache)
    assert logits.shape == (BATCH, 1, cfg.vocab_size), arch
    assert not bool(jnp.isnan(logits).any()), arch
    assert int(cache["t"]) == 1


def test_full_config_matches_assignment(arch_setup):
    """The non-smoke config must carry the exact published spec."""
    arch, _, _ = arch_setup
    full = get_config(arch)
    spec = {
        "whisper_large_v3": (32, 1280, 20, 20, 5120, 51866),
        "llama4_scout_17b_a16e": (48, 5120, 40, 8, 8192, 202048),
        "chatglm3_6b": (28, 4096, 32, 2, 13696, 65024),
        "deepseek_67b": (95, 8192, 64, 8, 22016, 102400),
        "zamba2_2p7b": (54, 2560, 32, 32, 10240, 32000),
        "starcoder2_3b": (30, 3072, 24, 2, 12288, 49152),
        "granite_moe_1b_a400m": (24, 1024, 16, 8, 512, 49155),
        "qwen1p5_0p5b": (24, 1024, 16, 16, 2816, 151936),
        "internvl2_1b": (24, 896, 14, 2, 4864, 151655),
        "mamba2_780m": (48, 1536, 0, 0, 0, 50280),
    }[arch]
    got = (full.num_layers, full.d_model, full.num_heads, full.num_kv_heads,
           full.d_ff, full.vocab_size)
    assert got == spec, (arch, got, spec)
    assert full.source, arch  # citation present


def test_moe_and_ssm_extras():
    llama4 = get_config("llama4-scout-17b-a16e")
    assert (llama4.num_experts, llama4.top_k) == (16, 1)
    granite = get_config("granite-moe-1b-a400m")
    assert (granite.num_experts, granite.top_k) == (32, 8)
    zamba = get_config("zamba2-2.7b")
    assert zamba.ssm_state == 64 and zamba.attn_every > 0
    mamba = get_config("mamba2-780m")
    assert mamba.ssm_state == 128
