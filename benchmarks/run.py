"""Benchmark harness — one entry per paper table/figure + system micro-
benchmarks.  Prints ``name,us_per_call,derived`` CSV lines.

Paper mapping:
  fig8_pareto_*      -> Fig. 8   (Pareto fronts, IID vs non-IID)
  table4_vs_baseline -> Table IV (High/Knee vs fixed ResNet-role model)
  fig9_realtime      -> Fig. 9   (stability of best/knee during search)
  sec4g_rt_vs_offline-> Sec. IV.G (per-generation cost, RT vs offline)
  roofline_*         -> EXPERIMENTS.md §Roofline (from dry-run records)
Micro:
  nsga2_select, fill_aggregate_{xla,pallas}, client_update, evaluate,
  fused_ce_vs_naive, kernel_* (interpret-mode correctness + call overhead)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(fn, n=3, warmup=1):
    for _ in range(warmup):
        fn()
    t0 = time.time()
    for _ in range(n):
        fn()
    return (time.time() - t0) / n * 1e6  # us


def emit(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}")


def bench_micro():
    from repro.core import nsga2
    rng = np.random.default_rng(0)
    objs = rng.random((200, 2))
    emit("nsga2_select_n200", _timeit(lambda: nsga2.select(objs, 100)),
         f"fronts={len(nsga2.fast_non_dominated_sort(objs))}")

    from repro.kernels import ops, ref
    m, p = 8, 1_000_000
    cl = jnp.asarray(rng.normal(size=(m, p)), jnp.float32)
    mk = jnp.asarray(rng.integers(0, 2, (m, p)), jnp.float32)
    w = jnp.full((m,), 1.0 / m)
    prev = jnp.asarray(rng.normal(size=(p,)), jnp.float32)
    r = ref.fill_aggregate(cl, mk, w, prev)
    emit("fill_aggregate_xla_8x1M",
         _timeit(lambda: jax.block_until_ready(
             ref.fill_aggregate(cl, mk, w, prev))),
         "bytes=%d" % (cl.nbytes * 2))
    out = ops.fill_aggregate(cl, mk, w, prev)
    err = float(jnp.abs(out - r).max())
    emit("fill_aggregate_pallas_interp_8x1M",
         _timeit(lambda: jax.block_until_ready(
             ops.fill_aggregate(cl, mk, w, prev)), n=1),
         f"allclose_err={err:.1e}")

    q = jnp.asarray(rng.normal(size=(1, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    o1 = ops.flash_attention(q, k, v)
    o2 = ref.flash_attention(q, k, v)
    emit("kernel_flash_attn_interp_s256",
         _timeit(lambda: jax.block_until_ready(
             ops.flash_attention(q, k, v)), n=1),
         f"allclose_err={float(jnp.abs(o1 - o2).max()):.1e}")

    from repro.models.layers import cross_entropy, fused_cross_entropy
    h = jnp.asarray(rng.normal(size=(4, 512, 256)), jnp.float32)
    table = jnp.asarray(rng.normal(size=(8192, 256)), jnp.float32) * 0.05
    labels = jnp.asarray(rng.integers(0, 8192, (4, 512)), jnp.int32)
    naive = jax.jit(lambda h_, t_, l_: cross_entropy(
        jnp.einsum("bsd,vd->bsv", h_, t_), l_))
    fused = jax.jit(lambda h_, t_, l_: fused_cross_entropy(h_, t_, l_,
                                                           chunk=512))
    us_n = _timeit(lambda: jax.block_until_ready(naive(h, table, labels)))
    us_f = _timeit(lambda: jax.block_until_ready(fused(h, table, labels)))
    emit("fused_ce_vs_naive", us_f, f"naive_us={us_n:.1f}")


def bench_federated(generations: int):
    from benchmarks import fed_nas
    api = fed_nas.build_api()
    clients = fed_nas.build_clients(6, iid=True, n=1200)

    xb, yb = clients[0].train
    from repro.core.federated import make_client_update, make_evaluator
    update = make_client_update(api)
    evaluate = make_evaluator(api)
    key = jnp.asarray(np.array([1, 2, 3, 0]), jnp.int32)
    params = api.init(jax.random.PRNGKey(0))
    jax.block_until_ready(update(params, key, xb, yb, 0.1))
    emit("client_update_1epoch", _timeit(
        lambda: jax.block_until_ready(update(params, key, xb, yb, 0.1)),
        n=2), f"batches={xb.shape[0]}")
    emit("client_evaluate", _timeit(
        lambda: jax.block_until_ready(evaluate(params, key, *clients[0].test)),
        n=2), "")

    # Sec IV.G: RT vs offline per-generation cost
    t0 = time.time()
    hist_rt = fed_nas.run_rt(api, clients, generations, population=4)
    rt_s = (time.time() - t0) / generations
    t0 = time.time()
    off_gens = max(1, generations // 2)
    hist_off = fed_nas.run_offline(api, clients, off_gens, population=4)
    off_s = (time.time() - t0) / off_gens
    ratio = off_s / rt_s
    emit("sec4g_rt_per_generation", rt_s * 1e6,
         f"passes={hist_rt['train_passes'][-1]}")
    emit("sec4g_offline_per_generation", off_s * 1e6,
         f"speedup_rt={ratio:.1f}x;paper_claims>=5x")
    emit("sec4g_upload_gb_rt", hist_rt["up_gb"][-1] * 1e6,
         f"offline_gb={hist_off['up_gb'][-1]:.4f}")

    # Fig 8 Pareto front + Fig 9 stability + Table IV vs fixed baseline
    front = fed_nas.summarize_front(api, hist_rt)
    emit("fig8_pareto_iid", len(front),
         ";".join(f"err={r['err']:.3f}@{r['flops']/1e6:.1f}MMac"
                  for r in front[:4]))
    best_curve = hist_rt["best_err"]
    emit("fig9_realtime_best_err_final", best_curve[-1] * 1e6,
         f"start={best_curve[0]:.3f};min={min(best_curve):.3f}")

    base = fed_nas.run_fixed_baseline(api, clients, rounds=generations)
    high = min(front, key=lambda r: r["err"])
    from repro.core import nsga2
    if len(front) > 1:
        knee_objs = np.asarray([[r["err"], r["flops"]] for r in front])
        knee = front[nsga2.knee_point(knee_objs, list(range(len(front))))]
    else:
        knee = high
    emit("table4_vs_baseline", base["err"][-1] * 1e6,
         f"high_err={high['err']:.3f};knee_err={knee['err']:.3f};"
         f"base_flops={base['flops']/1e6:.1f}M;"
         f"high_flops={high['flops']/1e6:.1f}M;"
         f"knee_flops={knee['flops']/1e6:.1f}M")


def bench_rt_property():
    """Hillclimb C2 (EXPERIMENTS §Perf): the supernet's traced choice key
    means ONE compilation serves every sub-model in the population — the
    property that makes the search real-time on the server.  Compare wall
    time of N distinct keys through the traced-key step vs re-jitting a
    static-key step per key (what per-key PyTorch module rebuilds cost)."""
    import numpy as np
    from repro.configs import get_config
    from repro.launch.train import init_opt, make_train_step
    from repro.models import transformer as tr

    cfg = get_config("qwen1.5-0.5b", smoke=True).replace(supernet=True)
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    opt = init_opt(params)
    rng = np.random.default_rng(0)
    keys = [jnp.asarray(rng.integers(0, 4, cfg.num_layers), jnp.int32)
            for _ in range(5)]
    batch = {"tokens": jnp.zeros((2, 64), jnp.int32),
             "labels": jnp.zeros((2, 64), jnp.int32)}

    step = jax.jit(make_train_step(cfg, remat=False))
    jax.block_until_ready(
        step(params, opt, dict(batch, choice_key=keys[0]))[2])  # compile once
    t0 = time.time()
    for k in keys:
        jax.block_until_ready(step(params, opt, dict(batch, choice_key=k))[2])
    traced_s = time.time() - t0

    t0 = time.time()
    for k in keys:
        fn = jax.jit(lambda p, o, b, kk=k: make_train_step(cfg, remat=False)(
            p, o, dict(b, choice_key=kk)))
        jax.block_until_ready(fn(params, opt, batch)[2])
    static_s = time.time() - t0
    emit("c2_realtime_traced_5keys", traced_s / 5 * 1e6,
         f"static_rejit_us={static_s/5*1e6:.0f};speedup={static_s/traced_s:.1f}x")


def bench_roofline():
    from benchmarks import roofline_table
    recs = roofline_table.load_records()
    counts = {}
    for r in recs:
        d = r.get("dominant", "?")
        counts[d] = counts.get(d, 0) + 1
    emit("roofline_records", len(recs),
         ";".join(f"{k}={v}" for k, v in sorted(counts.items())))
    for r in recs:
        if "compute_s" not in r:
            continue
        emit(f"roofline_{r['arch']}_{r['shape']}",
             max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
             f"bound={r['dominant']};model/hlo="
             f"{r.get('useful_flops_ratio', 0):.2f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--generations", type=int, default=2,
                    help="NAS generations for the federated benches")
    ap.add_argument("--skip-federated", action="store_true")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    bench_micro()
    bench_rt_property()
    if not args.skip_federated:
        bench_federated(args.generations)
    bench_roofline()


if __name__ == "__main__":
    main()
