"""Shared harness for the paper-shaped federated NAS experiments.

Scaled to this container (16x16 synthetic images, tens of generations) —
the *relative* claims of the paper (RT vs offline cost, Pareto shape,
FLOPs reduction vs the fixed baseline) are what the benchmarks validate;
see DESIGN.md Section 8 for the simulation boundary.

Everything routes through ``repro.engine.FedEngine``; the
``engine_backend`` argument selects the client-execution path ("loop" =
reference per-pair dispatch, "vmap" = ClientBatch-stacked, "mesh" =
population sharded over a jax device mesh).  Run

    PYTHONPATH=src python benchmarks/fed_nas.py

to compare the backend variants — loop, vmap and mesh, each of the
batched pair with the fused-generation path on AND off — on the default
cross-device config (many small clients — the regime where dispatch
count, not compute, is the bottleneck) AND the payload codecs
(``--mode codecs``: per-codec wire bytes, compression ratio vs fp32,
and the int8+error-feedback vs fp32 search trajectory; ``--out`` writes
the JSON that ``benchmarks/results/`` tracks).  ``--mode availability``
sweeps the real-time client model (``ClientSimConfig``): 0-50%
post-download dropout under IID and Dirichlet partitions plus a
deterministic-straggler scenario, reporting search quality, survivor
counts and the wasted-download ledger.  ``--mode scale`` sweeps the
client axis 10^2 -> 10^6 at a fixed per-round participant count over the
lazy stack (``VirtualClassification`` sample source + index-space
``partition_iid`` + ``ClientFleet``): per-round wall time and peak live
bytes must stay flat — fleet size only ever touches O(num_clients)
integer vectors, never materialized data — and the sweep lands in
``benchmarks/results/scale.json`` plus a ``"scale"`` point inside
``BENCH_engine.json``.  ``--mode backends``
writes ``BENCH_engine.json`` (dispatches/gen, wall-clock/gen, peak live
bytes per variant, the fused speedups and the scalar-vs-batched-key
measurement) — the repo root keeps the CI-host point of that perf
trajectory and CI uploads it as an artifact.  ``--mode obs`` measures
the telemetry subsystem itself (``repro.obs``): steady-state overhead
with telemetry on vs off at the same dispatch-bound point (<3%
acceptance, recorded as an ``"obs"`` block inside
``BENCH_engine.json``), the phase-time breakdown from the structured
round events, the fused recompile counters (a nonzero exit on any
unexpected retrace — the CI gate) and a JSONL round-event log
(``--obs-out``).  As a script it forces an
8-way host device mesh (``--xla_force_host_platform_device_count=8``)
so the mesh backend has devices to shard over; equivalently set
XLA_FLAGS yourself.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional

if __name__ == "__main__" and "xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    # must happen before the first jax import; library importers
    # (examples, tests) are left untouched
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8").strip()

import jax
import numpy as np

from repro.configs import get_config
from repro.core import make_api, nsga2
from repro.data import ClientFleet, VirtualClassification, \
    make_classification, make_clients, partition_dirichlet, partition_iid, \
    partition_label
from repro.engine import ClientSimConfig, FedAvgBaseline, FedEngine, \
    OfflineNas, RealTimeNas, RunConfig
from repro.obs import PeakLiveBytes, steady_mean

IMAGE = 16
RESNET_LIKE_KEY = np.ones(4, dtype=np.int32)   # all-residual master path


def build_clients(num_clients: int, iid: bool = True, seed: int = 0,
                  n: int = 2000, batch: int = 50, test_batch: int = 50,
                  image: int = IMAGE, partition: Optional[str] = None):
    x, y = make_classification(seed, n, image=image, signal=1.2, noise=0.8)
    partition = partition or ("iid" if iid else "label")
    if partition == "iid":
        shards = partition_iid(seed, n, num_clients)
    elif partition == "label":
        shards = partition_label(seed, y, num_clients, classes_per_client=5)
    elif partition == "dirichlet":
        shards = partition_dirichlet(seed, y, num_clients, alpha=0.5)
    else:
        raise ValueError(f"unknown partition {partition!r}")
    return make_clients(x, y, shards, batch=batch, test_batch=test_batch)


def build_api():
    return make_api(get_config("cifar-supernet", smoke=True))


def run_rt(api, clients, generations: int, population: int = 6,
           seed: int = 0, backend: str = "xla",
           engine_backend: str = "loop") -> Dict:
    rc = RunConfig(population=population, generations=generations,
                   seed=seed, aggregate_backend=backend,
                   backend=engine_backend)
    return FedEngine(api, clients, rc,
                     strategy=RealTimeNas()).run().history()


def run_offline(api, clients, generations: int, population: int = 6,
                seed: int = 0, engine_backend: str = "loop") -> Dict:
    rc = RunConfig(population=population, generations=generations,
                   seed=seed, backend=engine_backend)
    return FedEngine(api, clients, rc,
                     strategy=OfflineNas()).run().history()


def run_fixed_baseline(api, clients, rounds: int, key=RESNET_LIKE_KEY,
                       seed: int = 0, engine_backend: str = "loop") -> Dict:
    """FedAvg on a fixed architecture (the paper's ResNet18 role)."""
    rc = RunConfig(generations=rounds, seed=seed, backend=engine_backend)
    res = FedEngine(api, clients, rc,
                    strategy=FedAvgBaseline(key)).run()
    return {"err": [r.best_err for r in res.reports],
            "flops": res.extras["flops"],
            "params": res.extras["params"],
            "stats": res.stats}


def _max_param_diff(a, b) -> float:
    return float(max(
        np.abs(np.asarray(p) - np.asarray(q)).max()
        for p, q in zip(jax.tree.leaves(a.extras["final_master"]),
                        jax.tree.leaves(b.extras["final_master"]))))


def _max_err_diff(a, b) -> float:
    return float(max(
        np.abs(np.asarray(x.objs) - np.asarray(y.objs)).max()
        for x, y in zip(a.reports, b.reports)))


def _variant(name: str):
    """'vmap' -> ('vmap', fused=True); 'vmap-nofused' -> ('vmap', False).
    The loop backend has no fused path (the flag is ignored there)."""
    base, _, suffix = name.partition("-")
    return base, suffix != "nofused"


BACKEND_VARIANTS = ("loop", "vmap", "vmap-nofused", "mesh", "mesh-nofused")


def compare_backends(api=None, clients=None, generations: int = 5,
                     population: int = 10, seed: int = 0,
                     backends=BACKEND_VARIANTS) -> Dict:
    """Same search on every execution-backend variant (``'vmap'`` =
    fused, ``'vmap-nofused'`` = per-bucket dispatches, etc.): wall clock
    (total and steady-state per generation, from ``RoundReport.round_s``),
    dispatch counts, peak live bytes, and result agreement (vs the first
    variant, plus the fused-vs-nonfused and mesh-vs-vmap pairs the fused
    path is certified against).  The default client set is the
    paper-scale cross-device regime — population 10 over 16 clients with
    minibatch-sized local shards, one local pass per round — where
    dispatch overhead, not compute, bounds the generation wall clock
    (larger per-client workloads converge to hardware-limited, where
    fused ~= non-fused by construction; pass ``clients`` to measure that
    end)."""
    import dataclasses

    api = api or build_api()
    if clients is None:
        clients = build_clients(16, iid=True, n=64, batch=2,
                                test_batch=2, image=8)
    out: Dict = {"generations": generations, "population": population,
                 "clients": len(clients), "devices": len(jax.devices()),
                 "backends": list(backends)}
    hists = {}
    for name in backends:
        base, fused = _variant(name)
        eng = FedEngine(api, clients,
                        RunConfig(population=population,
                                  generations=generations, seed=seed,
                                  backend=base, fused=fused))
        # peak is growth over the pre-run baseline (PeakLiveBytes), so
        # arrays retained by earlier variants (their final masters in
        # `hists`) don't bias later variants' numbers
        pk = PeakLiveBytes()
        t0 = time.time()
        res = eng.run(callback=pk.sample)
        wall = time.time() - t0
        rounds = [r.round_s for r in res.reports]
        hists[name] = res
        out[name] = {"backend": base, "fused": fused,
                     "wall_s": wall,
                     "steady_gen_s": steady_mean(rounds),
                     "round_s": [round(r, 4) for r in rounds],
                     "peak_live_bytes": pk.growth,
                     "dispatches": eng.backend.dispatches,
                     "dispatches_per_gen": eng.backend.dispatches / generations}
    ref = hists[backends[0]]
    for name in backends[1:]:
        out[name]["max_err_diff"] = _max_err_diff(ref, hists[name])
        out[name]["max_param_diff"] = _max_param_diff(ref, hists[name])
    for base in ("vmap", "mesh"):      # the acceptance pair: fused wins
        f, nf = base, f"{base}-nofused"
        if f in hists and nf in hists:
            out[f"{base}_fused_vs_nonfused"] = {
                "steady_speedup": (out[nf]["steady_gen_s"]
                                   / out[f]["steady_gen_s"]),
                "total_speedup": out[nf]["wall_s"] / out[f]["wall_s"],
                "max_err_diff": _max_err_diff(hists[nf], hists[f]),
                "max_param_diff": _max_param_diff(hists[nf], hists[f]),
                "comm_stats_equal": dataclasses.asdict(hists[nf].stats)
                == dataclasses.asdict(hists[f].stats),
            }
    if "vmap" in hists and "mesh" in hists:
        out["mesh_vs_vmap"] = {
            "comm_stats_equal": dataclasses.asdict(hists["mesh"].stats)
            == dataclasses.asdict(hists["vmap"].stats),
            "max_param_diff": _max_param_diff(hists["vmap"], hists["mesh"]),
            "max_err_diff": _max_err_diff(hists["vmap"], hists["mesh"]),
        }
    if backends[0] == "loop" and "vmap" in hists:  # legacy two-way summary
        out["speedup_total"] = out["loop"]["wall_s"] / out["vmap"]["wall_s"]
        out["speedup_steady"] = (out["loop"]["steady_gen_s"]
                                 / out["vmap"]["steady_gen_s"])
        out["max_err_diff"] = out["vmap"]["max_err_diff"]
        out["max_param_diff"] = out["vmap"]["max_param_diff"]
    return out


def measure_key_batching(api=None, clients=None, n_keys: int = 12,
                         repeats: int = 3, seed: int = 0) -> Dict:
    """Re-measure the "batched keys lower ``lax.switch`` to
    compute-all-branches-and-select" trade, separately for training and
    forward-only evaluation, now that fused execution makes dispatch
    count equal (one program either way): scalar-key ``lax.scan`` vs
    batched-key ``vmap`` over the same stacked shards.  The winner per
    phase is the documented default — see docs/architecture.md "Fused
    generations"."""
    from repro.core.federated import client_update_fn, eval_count_fn

    api = api or build_api()
    if clients is None:
        clients = build_clients(8, iid=True, n=480, batch=20, test_batch=20)
    rng = np.random.default_rng(seed)
    keys = jax.numpy.asarray(
        rng.integers(0, 4, size=(n_keys, api.num_blocks)), np.int32)
    params = api.init(jax.random.PRNGKey(seed))
    ev = eval_count_fn(api)
    upd = client_update_fn(api, 1, 0.5)
    import jax.numpy as jnp
    exb = jnp.stack([jnp.asarray(c.test[0]) for c in clients])
    eyb = jnp.stack([jnp.asarray(c.test[1]) for c in clients])
    txb = jnp.stack([jnp.asarray(c.train[0]) for c in clients])
    tyb = jnp.stack([jnp.asarray(c.train[1]) for c in clients])

    def eval_one(p, key):
        def per_client(a, c):
            return a + ev(p, key, c[0], c[1]), None
        return jax.lax.scan(per_client, jnp.zeros((), jnp.int32),
                            (exb, eyb))[0]

    def train_one(p, key):
        def per_client(_, c):
            return None, upd(p, key, c[0], c[1], 0.05)
        return jax.lax.scan(per_client, None, (txb, tyb))[1]

    variants = {
        "eval": {
            "scalar_key_scan": jax.jit(lambda p, ks: jax.lax.scan(
                lambda _, k: (None, eval_one(p, k)), None, ks)[1]),
            "batched_key_vmap": jax.jit(lambda p, ks: jax.vmap(
                lambda k: eval_one(p, k))(ks)),
        },
        "train": {
            "scalar_key_scan": jax.jit(lambda p, ks: jax.lax.scan(
                lambda _, k: (None, train_one(p, k)), None, ks)[1]),
            "batched_key_vmap": jax.jit(lambda p, ks: jax.vmap(
                lambda k: train_one(p, k))(ks)),
        },
    }

    def bench(fn):
        jax.block_until_ready(fn(params, keys))      # compile
        t0 = time.time()
        for _ in range(repeats):
            jax.block_until_ready(fn(params, keys))
        return (time.time() - t0) / repeats

    rep: Dict = {"n_keys": n_keys, "clients": len(clients)}
    for phase, fns in variants.items():
        s = bench(fns["scalar_key_scan"])
        v = bench(fns["batched_key_vmap"])
        rep[phase] = {"scalar_key_scan_s": s, "batched_key_vmap_s": v,
                      "vmap_over_scan": v / s,
                      "winner": ("scalar_key_scan" if s <= v
                                 else "batched_key_vmap")}
    return rep


def compare_codecs(api=None, clients=None, generations: int = 3,
                   population: int = 6, seed: int = 0,
                   engine_backend: str = "vmap",
                   codecs=("none", "cast", "int8", "topk")) -> Dict:
    """Same search under every payload codec (applied to both wire
    directions): wire vs fp32-logical bytes, the compression ratio vs
    the ``none`` baseline, and the search-quality cost (final best test
    error vs fp32).  This is the comm trajectory the paper's "reduce the
    local payload" claim asks for — ``benchmarks/results/`` records it
    next to the dispatch counts."""
    api = api or build_api()
    if clients is None:
        clients = build_clients(8, iid=True, n=480, batch=20, test_batch=20)
    out: Dict = {"generations": generations, "population": population,
                 "clients": len(clients), "engine_backend": engine_backend,
                 "codecs": {}}
    codecs = tuple(codecs)
    if codecs[:1] != ("none",):       # the fp32 baseline anchors the ratios
        codecs = ("none",) + tuple(c for c in codecs if c != "none")
    base = None
    for codec in codecs:
        eng = FedEngine(api, clients,
                        RunConfig(population=population,
                                  generations=generations, seed=seed,
                                  backend=engine_backend,
                                  uplink_codec=codec,
                                  downlink_codec=codec))
        t0 = time.time()
        res = eng.run()
        s = res.stats
        rec = {"down_bytes": s.down_bytes, "up_bytes": s.up_bytes,
               "down_wire_bytes": s.down_wire_bytes,
               "up_wire_bytes": s.up_wire_bytes,
               "best_err": float(res.reports[-1].best_err),
               "wall_s": time.time() - t0}
        wire_total = s.down_wire_bytes + s.up_wire_bytes
        if codec == "none":
            base = res
        base_total = (base.stats.down_wire_bytes
                      + base.stats.up_wire_bytes)
        rec["compression_vs_fp32"] = base_total / wire_total
        rec["best_err_delta_vs_fp32"] = (
            rec["best_err"] - float(base.reports[-1].best_err))
        out["codecs"][codec] = rec
    return out


def codec_trajectory(api=None, clients=None, generations: int = 30,
                     population: int = 6, seed: int = 0,
                     codec: str = "int8",
                     engine_backend: str = "vmap") -> Dict:
    """Long-horizon search-quality check: ``codec`` (with the engine's
    server-side error feedback) vs fp32 over ``generations`` rounds on
    the synthetic task.  The acceptance bar is the final best test-error
    rates within 2 points — i.e. compression costs bytes, not search
    quality."""
    api = api or build_api()
    if clients is None:
        clients = build_clients(8, iid=True, n=480, batch=20, test_batch=20)
    runs = {}
    for name, spec in (("fp32", "none"), (codec, codec)):
        res = FedEngine(api, clients,
                        RunConfig(population=population,
                                  generations=generations, seed=seed,
                                  backend=engine_backend,
                                  uplink_codec=spec,
                                  downlink_codec=spec)).run()
        runs[name] = res
    best = {k: [float(r.best_err) for r in v.reports]
            for k, v in runs.items()}
    return {"generations": generations, "codec": codec,
            "best_err": best,
            "final_fp32": best["fp32"][-1], "final_codec": best[codec][-1],
            "final_delta": best[codec][-1] - best["fp32"][-1],
            "wire_ratio": ((runs["fp32"].stats.down_wire_bytes
                            + runs["fp32"].stats.up_wire_bytes)
                           / (runs[codec].stats.down_wire_bytes
                              + runs[codec].stats.up_wire_bytes))}


def compare_availability(api=None, generations: int = 10,
                         population: int = 6, seed: int = 0,
                         num_clients: int = 8, samples: int = 960,
                         dropouts=(0.0, 0.1, 0.3, 0.5),
                         partitions=("iid", "dirichlet"),
                         engine_backend: str = "vmap") -> Dict:
    """The real-time availability sweep the paper's headline claim asks
    for: the same search under 0-50% post-download dropout, on IID and
    Dirichlet(0.5) partitions.  Reports the final best test error, the
    survivor counts and the wasted-download ledger per setting, plus a
    deterministic-straggler scenario (slowdown 10x vs deadline 2.0 —
    the stragglers miss every round).  dropout=0.0 is the synchronous
    baseline: it reproduces the no-sim trajectory bit for bit, so the
    sweep's deltas are pure availability effects."""
    api = api or build_api()
    out: Dict = {"generations": generations, "population": population,
                 "clients": num_clients, "engine_backend": engine_backend,
                 "partitions": {}}
    for part in partitions:
        clients = build_clients(num_clients, seed=seed, n=samples,
                                batch=10, test_batch=10, image=8,
                                partition=part)
        rows = {}
        for rate in dropouts:
            sim = ClientSimConfig(dropout=rate, seed=seed + 1)
            res = FedEngine(api, clients,
                            RunConfig(population=population,
                                      generations=generations, seed=seed,
                                      lr0=0.05, backend=engine_backend,
                                      client_sim=sim)).run()
            s = res.stats
            rows[str(rate)] = {
                "best_err": float(res.reports[-1].best_err),
                "mean_survivors": (float(np.mean(
                    [r.n_survivors for r in res.reports]))
                    if sim.is_active else float(num_clients)),
                "dropped_total": (int(sum(r.n_dropped
                                          for r in res.reports))
                                  if sim.is_active else 0),
                "up_mb": s.up_bytes / 1e6,
                "down_mb": s.down_bytes / 1e6,
                "wasted_down_mb": s.wasted_down_bytes / 1e6,
                "wasted_frac_of_down": (s.wasted_down_bytes
                                        / max(s.down_bytes, 1.0)),
            }
        out["partitions"][part] = rows
    # deterministic stragglers: a third of the fleet 10x slower than a
    # 2.0-round deadline — they receive every broadcast and finish none
    clients = build_clients(num_clients, seed=seed, n=samples,
                            batch=10, test_batch=10, image=8,
                            partition="iid")
    sim = ClientSimConfig(straggler_fraction=1 / 3,
                          straggler_slowdown=10.0, round_deadline=2.0,
                          seed=seed + 1)
    res = FedEngine(api, clients,
                    RunConfig(population=population,
                              generations=generations, seed=seed,
                              lr0=0.05, backend=engine_backend,
                              client_sim=sim)).run()
    out["stragglers"] = {
        "config": {"fraction": 1 / 3, "slowdown": 10.0, "deadline": 2.0},
        "best_err": float(res.reports[-1].best_err),
        "mean_survivors": float(np.mean([r.n_survivors
                                         for r in res.reports])),
        "wasted_down_mb": res.stats.wasted_down_bytes / 1e6,
        "wasted_frac_of_down": (res.stats.wasted_down_bytes
                                / max(res.stats.down_bytes, 1.0)),
    }
    return out


def scale_sweep(api=None,
                client_counts=(100, 1000, 10000, 100000, 1000000),
                sampled: int = 16, generations: int = 4,
                population: int = 10, seed: int = 0,
                samples_per_client: int = 8, image: int = 8,
                batch: int = 2, engine_backend: str = "vmap") -> Dict:
    """The million-client axis: the same search at a FIXED per-round
    participant count (``sampled``) while the fleet grows 10^2 -> 10^6.

    Every fleet is fully lazy — a ``VirtualClassification`` source (no
    dense dataset ever exists), an index-space ``partition_iid`` (one
    permutation + one cut vector) and a ``ClientFleet`` that
    materializes only the clients a round actually samples.  The
    acceptance claim is flatness: per-round steady-state wall time and
    peak live bytes within 2x across the whole sweep, because nothing
    downstream of participant sampling ever scales with ``len(fleet)``.
    ``partition_host_bytes`` (the O(dataset) permutation) is reported
    separately — it is the one intentionally size-dependent cost."""
    api = api or build_api()
    out: Dict = {"sampled": sampled, "generations": generations,
                 "population": population, "engine_backend": engine_backend,
                 "samples_per_client": samples_per_client,
                 "devices": len(jax.devices()), "points": {}}
    steadies, peaks = [], []
    for k in client_counts:
        n = k * samples_per_client
        t0 = time.time()
        source = VirtualClassification(seed, n, image=image,
                                       signal=1.2, noise=0.8)
        part = partition_iid(seed, n, k)
        fleet = ClientFleet(source, part, batch=batch, test_batch=batch,
                            cache_size=4 * sampled)
        build_s = time.time() - t0
        eng = FedEngine(api, fleet,
                        RunConfig(population=population,
                                  generations=generations, seed=seed,
                                  participation=sampled / k,
                                  backend=engine_backend))
        pk = PeakLiveBytes()
        t0 = time.time()
        res = eng.run(callback=pk.sample)
        wall = time.time() - t0
        rounds = [r.round_s for r in res.reports]
        steady = steady_mean(rounds)   # round 1 pays compile; excluded
        steadies.append(steady)
        peaks.append(pk.growth)
        out["points"][str(k)] = {
            "clients": k, "participation": sampled / k,
            "build_s": build_s, "wall_s": wall,
            "steady_round_s": steady,
            "round_s": [round(r, 4) for r in rounds],
            "peak_live_bytes": pk.growth,
            "partition_host_bytes": part.nbytes,
            "clients_materialized": fleet.materialized,
            "clients_cached": fleet.cached,
            "best_err": float(res.reports[-1].best_err),
        }
    # flatness over the WHOLE sweep (max/min, not endpoints — a bulge in
    # the middle is just as much a scaling leak)
    steady_ratio = max(steadies) / min(steadies)
    peak_ratio = max(peaks) / max(min(peaks), 1)
    out["summary"] = {
        "client_counts": list(client_counts),
        "steady_round_s": steadies,
        "peak_live_bytes": peaks,
        "steady_round_ratio": steady_ratio,
        "peak_live_ratio": peak_ratio,
        "flat_within_2x": steady_ratio < 2.0 and peak_ratio < 2.0,
    }
    return out


def measure_telemetry(api=None, clients=None, generations: int = 25,
                      population: int = 10, seed: int = 0,
                      engine_backend: str = "vmap", repeats: int = 3,
                      jsonl_path: Optional[str] = None) -> Dict:
    """Measure the telemetry subsystem itself (``repro.obs``) at the
    dispatch-bound backends point: steady-state per-generation wall time
    with ``RunConfig.telemetry`` off vs on (the <3% acceptance bar), the
    phase-time breakdown from the structured round events, and the fused
    recompile counters — ``fused_fill`` / ``fused_eval_shared`` must
    trace exactly once, and no program may retrace after round 1
    (``retrace_ok`` is the CI gate).

    ``repeats`` off/on pairs are interleaved (alternating which side
    leads each pair) and the *minimum steady round* of each side is
    compared (every round after the compile
    round, pooled across repeats).  Min, not mean: scheduler/contention
    noise is one-sided — it inflates a round but never deflates one —
    and on a shared machine it dwarfs the effect being measured
    (run-mean swings of ±20% are routine), so the per-side floor is the
    faithful estimate of what telemetry itself costs.  Timing runs use
    a memory sink; the last telemetry-on run writes the JSONL
    round-event log when ``jsonl_path`` is given (file recreated: one
    run's events, one line per generation)."""
    api = api or build_api()
    if clients is None:
        clients = build_clients(16, iid=True, n=64, batch=2,
                                test_batch=2, image=8)

    def run(telemetry):
        eng = FedEngine(api, clients,
                        RunConfig(population=population,
                                  generations=generations, seed=seed,
                                  backend=engine_backend,
                                  telemetry=telemetry))
        t0 = time.time()
        res = eng.run()
        return res, time.time() - t0

    repeats = max(1, repeats)
    off_rounds, on_rounds = [], []
    res_off = res_on = wall_off = wall_on = None
    for i in range(repeats):
        sink = "memory"
        if i == repeats - 1 and jsonl_path:
            os.makedirs(os.path.dirname(jsonl_path) or ".", exist_ok=True)
            if os.path.exists(jsonl_path):
                os.remove(jsonl_path)  # one run's events, not an append log
            sink = f"jsonl:{jsonl_path}"
        # alternate which side of the pair runs first: process-age
        # effects (allocator warm-up, growing jit caches) would
        # otherwise bias whichever side always ran second
        if i % 2 == 0:
            res_off, wall_off = run(None)
            res_on, wall_on = run({"sink": sink})
        else:
            res_on, wall_on = run({"sink": sink})
            res_off, wall_off = run(None)
        off_rounds += [r.round_s for r in res_off.reports[1:]
                       or res_off.reports]
        on_rounds += [r.round_s for r in res_on.reports[1:]
                      or res_on.reports]

    tel = res_on.telemetry
    off_best, on_best = min(off_rounds), min(on_rounds)
    unexpected = {k: v for k, v in tel.trace_counts.items() if v > 1}
    late = {str(e.gen): e.recompiles for e in tel.events[1:] if e.recompiles}
    return {
        "generations": generations, "population": population,
        "clients": len(clients), "engine_backend": engine_backend,
        "repeats": repeats,
        "steady_gen_s_off": off_best, "steady_gen_s_on": on_best,
        "wall_s_off": wall_off, "wall_s_on": wall_on,
        "overhead_frac": (on_best - off_best) / off_best,
        "overhead_under_3pct": (on_best - off_best) / off_best < 0.03,
        # the zero-overhead claim is about numerics before it is about
        # time: on and off must agree bit for bit
        "masters_bitwise_equal": _max_param_diff(res_off, res_on) == 0.0,
        "max_err_diff": _max_err_diff(res_off, res_on),
        "trace_counts": dict(tel.trace_counts),
        "unexpected_retraces": unexpected,
        "late_recompiles": late,
        "retrace_ok": not unexpected and not late,
        "phase_totals": {k: round(v, 4)
                         for k, v in sorted(tel.phase_totals().items())},
        "events": len(tel.events),
        "jsonl_path": jsonl_path,
    }


def summarize_front(api, hist) -> List[Dict]:
    """Final-generation Pareto front -> [{key, err, flops}] (Fig 8)."""
    objs = hist["objs"][-1]
    sel = nsga2.select(objs, len(hist["parent_keys"][-1]))
    front = nsga2.fast_non_dominated_sort(objs[sel])[0]
    out = []
    for i in front:
        out.append({"err": float(objs[sel][i, 0]),
                    "flops": float(objs[sel][i, 1])})
    out.sort(key=lambda r: r["flops"])
    return out


def save_history(path: str, hist: Dict, extra: Optional[Dict] = None):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    rec = {
        "gen": hist["gen"],
        "best_err": hist["best_err"],
        "knee_err": hist.get("knee_err"),
        "down_gb": hist["down_gb"],
        "up_gb": hist["up_gb"],
        "train_passes": hist["train_passes"],
        "wall_s": hist["wall_s"],
        "final_objs": np.asarray(hist["objs"][-1]).tolist(),
    }
    if extra:
        rec.update(extra)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def _run_backend_mode(args) -> Dict:
    clients = build_clients(args.clients, iid=True, n=args.samples,
                            batch=args.batch, test_batch=args.batch,
                            image=args.image)
    api = build_api()
    population = 10 if args.population is None else args.population
    # 25 generations by default: steady-state is ~30 ms/gen at the
    # dispatch-bound point, so short runs read timer noise — and the
    # recorded repo-root BENCH_engine.json must stay comparable run to
    # run (CI uses the same default)
    gens = 25 if args.generations is None else args.generations
    rep = compare_backends(api, clients, generations=gens,
                           population=population, seed=args.seed,
                           backends=tuple(args.backends))
    print(f"{rep['clients']} clients x {rep['generations']} generations, "
          f"population {rep['population']}, {rep['devices']} devices")
    ref = args.backends[0]
    for bk in args.backends:
        r = rep[bk]
        agree = (f" | vs {ref}: err {r['max_err_diff']:.1e} "
                 f"params {r['max_param_diff']:.1e}"
                 if "max_err_diff" in r else "")
        print(f"{bk:>13}: total {r['wall_s']:7.1f}s | steady "
              f"{r['steady_gen_s']:6.2f}s/gen | "
              f"{r['dispatches_per_gen']:7.1f} dispatches/gen | "
              f"{r['peak_live_bytes'] / 1e6:7.1f} MB live{agree}")
    if "speedup_total" in rep:
        print(f"vmap speedup vs loop: {rep['speedup_total']:.2f}x total, "
              f"{rep['speedup_steady']:.2f}x steady-state")
    for base in ("vmap", "mesh"):
        key = f"{base}_fused_vs_nonfused"
        if key in rep:
            fv = rep[key]
            print(f"{base} fused vs non-fused: "
                  f"{fv['steady_speedup']:.2f}x steady | err diff "
                  f"{fv['max_err_diff']:.1e} | param diff "
                  f"{fv['max_param_diff']:.1e} | CommStats equal: "
                  f"{fv['comm_stats_equal']}")
    if "mesh_vs_vmap" in rep:
        mv = rep["mesh_vs_vmap"]
        print(f"mesh vs vmap: CommStats equal: {mv['comm_stats_equal']} | "
              f"max err diff {mv['max_err_diff']:.2e} | "
              f"max master-param diff {mv['max_param_diff']:.2e}")
    if args.key_batching:
        kb = measure_key_batching(api)
        rep["key_batching"] = kb
        for phase in ("train", "eval"):
            r = kb[phase]
            print(f"key batching [{phase}]: scalar-key scan "
                  f"{r['scalar_key_scan_s']:.3f}s vs batched-key vmap "
                  f"{r['batched_key_vmap_s']:.3f}s "
                  f"({r['vmap_over_scan']:.2f}x) -> {r['winner']}")
    if args.bench_out:
        with open(args.bench_out, "w") as f:
            json.dump(rep, f, indent=1)
        print(f"wrote {args.bench_out}")
    return rep


def _run_codec_mode(args) -> Dict:
    api = build_api()
    population = 6 if args.population is None else args.population
    gens = 3 if args.generations is None else args.generations
    clients = build_clients(args.codec_clients, iid=True,
                            n=args.codec_samples, batch=20, test_batch=20)
    rep = compare_codecs(api, clients, generations=gens,
                         population=population, seed=args.seed,
                         codecs=tuple(args.codecs))
    print(f"\ncodecs ({rep['clients']} clients x {rep['generations']} "
          f"generations, population {rep['population']}, "
          f"{rep['engine_backend']} backend):")
    for codec, r in rep["codecs"].items():
        print(f"{codec:>6}: down {r['down_wire_bytes'] / 1e6:8.2f} MB | "
              f"up {r['up_wire_bytes'] / 1e6:8.2f} MB | "
              f"{r['compression_vs_fp32']:5.2f}x vs fp32 | "
              f"best err {r['best_err']:.3f} "
              f"({r['best_err_delta_vs_fp32']:+.3f})")
    if args.trajectory_generations > 0:
        traj = codec_trajectory(api, clients,
                                generations=args.trajectory_generations,
                                population=population, seed=args.seed)
        rep["trajectory"] = traj
        print(f"{traj['codec']}+EF vs fp32 over "
              f"{traj['generations']} generations: final err "
              f"{traj['final_codec']:.3f} vs {traj['final_fp32']:.3f} "
              f"(delta {traj['final_delta']:+.3f}) at "
              f"{traj['wire_ratio']:.2f}x fewer wire bytes")
    return rep


def _run_availability_mode(args) -> Dict:
    api = build_api()
    population = 6 if args.population is None else args.population
    gens = 10 if args.generations is None else args.generations
    rep = compare_availability(api, generations=gens, population=population,
                               seed=args.seed,
                               num_clients=args.avail_clients,
                               samples=args.avail_samples,
                               dropouts=tuple(args.dropouts))
    print(f"\navailability ({rep['clients']} clients x {rep['generations']} "
          f"generations, population {rep['population']}, "
          f"{rep['engine_backend']} backend):")
    for part, rows in rep["partitions"].items():
        for rate, r in rows.items():
            print(f"{part:>9} dropout {float(rate):4.2f}: best err "
                  f"{r['best_err']:.3f} | surv {r['mean_survivors']:4.1f} | "
                  f"up {r['up_mb']:7.2f} MB | wasted down "
                  f"{r['wasted_down_mb']:7.2f} MB "
                  f"({100 * r['wasted_frac_of_down']:4.1f}% of down)")
    s = rep["stragglers"]
    print(f"stragglers (1/3 at 10x vs deadline 2.0): best err "
          f"{s['best_err']:.3f} | surv {s['mean_survivors']:4.1f} | "
          f"wasted down {s['wasted_down_mb']:7.2f} MB "
          f"({100 * s['wasted_frac_of_down']:4.1f}% of down)")
    return rep


def _run_scale_mode(args) -> Dict:
    api = build_api()
    population = 10 if args.population is None else args.population
    gens = 4 if args.generations is None else args.generations
    rep = scale_sweep(api, client_counts=tuple(args.scale_clients),
                      sampled=args.scale_sampled, generations=gens,
                      population=population, seed=args.seed)
    print(f"\nscale ({args.scale_sampled} sampled/round x {gens} "
          f"generations, population {rep['population']}, "
          f"{rep['engine_backend']} backend):")
    for k, r in rep["points"].items():
        print(f"{int(k):>9} clients: build {r['build_s']:6.2f}s | steady "
              f"{r['steady_round_s']:6.2f}s/round | peak "
              f"{r['peak_live_bytes'] / 1e6:7.1f} MB live | partition "
              f"{r['partition_host_bytes'] / 1e6:7.1f} MB host | "
              f"{r['clients_materialized']:3d} clients ever built")
    s = rep["summary"]
    print(f"steady-round ratio {s['steady_round_ratio']:.2f}x, peak-bytes "
          f"ratio {s['peak_live_ratio']:.2f}x across "
          f"{s['client_counts'][0]} -> {s['client_counts'][-1]} clients "
          f"(flat within 2x: {s['flat_within_2x']})")
    if args.scale_out:
        os.makedirs(os.path.dirname(args.scale_out) or ".", exist_ok=True)
        with open(args.scale_out, "w") as f:
            json.dump(rep, f, indent=1)
        print(f"wrote {args.scale_out}")
    if args.bench_out:
        # fold the summary into the recorded perf trajectory next to the
        # backend timings (leave their keys untouched)
        bench = {}
        if os.path.exists(args.bench_out):
            with open(args.bench_out) as f:
                bench = json.load(f)
        bench["scale"] = rep["summary"]
        with open(args.bench_out, "w") as f:
            json.dump(bench, f, indent=1)
        print(f"merged scale summary into {args.bench_out}")
    return rep


def _run_obs_mode(args) -> Dict:
    api = build_api()
    clients = build_clients(args.clients, iid=True, n=args.samples,
                            batch=args.batch, test_batch=args.batch,
                            image=args.image)
    population = 10 if args.population is None else args.population
    gens = 25 if args.generations is None else args.generations
    rep = measure_telemetry(api, clients, generations=gens,
                            population=population, seed=args.seed,
                            jsonl_path=args.obs_out or None)
    print(f"\nobs ({rep['clients']} clients x {rep['generations']} "
          f"generations, population {rep['population']}, "
          f"{rep['engine_backend']} backend):")
    print(f"steady gen: {rep['steady_gen_s_off'] * 1e3:7.1f} ms off | "
          f"{rep['steady_gen_s_on'] * 1e3:7.1f} ms on | overhead "
          f"{100 * rep['overhead_frac']:+.2f}% (target <3%: "
          f"{rep['overhead_under_3pct']}) | masters bitwise equal: "
          f"{rep['masters_bitwise_equal']}")
    total = sum(rep["phase_totals"].values()) or 1.0
    for path, s in rep["phase_totals"].items():
        print(f"  {path:<24} {s:8.3f}s ({100 * s / total:5.1f}% of "
              "span time)")
    print(f"trace counts: {rep['trace_counts']} | retrace ok: "
          f"{rep['retrace_ok']}")
    if args.obs_out:
        print(f"wrote {rep['events']} round events to {args.obs_out}")
    if args.bench_out:
        # fold into the recorded perf trajectory next to the backend
        # timings and the scale summary (leave their keys untouched)
        bench = {}
        if os.path.exists(args.bench_out):
            with open(args.bench_out) as f:
                bench = json.load(f)
        bench["obs"] = rep
        with open(args.bench_out, "w") as f:
            json.dump(bench, f, indent=1)
        print(f"merged obs summary into {args.bench_out}")
    if not rep["retrace_ok"]:
        # the CI gate: a fused program that traces more than once (or any
        # program that retraces after round 1) is a silent perf regression
        raise SystemExit(
            f"unexpected fused retraces: trace_counts={rep['trace_counts']} "
            f"late={rep['late_recompiles']}")
    return rep


def main():
    import argparse
    ap = argparse.ArgumentParser(
        description="execution-backend, payload-codec and "
                    "client-availability comparisons")
    ap.add_argument("--mode",
                    choices=["backends", "codecs", "availability", "scale",
                             "obs", "both", "all"],
                    default="both")
    ap.add_argument("--generations", type=int, default=None,
                    help="defaults to 25 in backends mode (steady-state "
                         "per-gen times are ~30 ms — shorter runs read "
                         "timer noise) and 3 in codecs mode")
    ap.add_argument("--population", type=int, default=None,
                    help="defaults to 10 in backends mode (the recorded "
                         "perf point) and 6 in codecs mode")
    ap.add_argument("--clients", type=int, default=16,
                    help="backends mode: client count — default is the "
                         "paper-scale dispatch-bound point BENCH_engine"
                         ".json records (the codec mode has its own "
                         "--codec-* sizing)")
    ap.add_argument("--samples", type=int, default=64,
                    help="backends mode: total samples")
    ap.add_argument("--image", type=int, default=8,
                    help="backends mode: image size")
    ap.add_argument("--batch", type=int, default=2,
                    help="backends mode: per-client batch size")
    ap.add_argument("--codec-clients", type=int, default=8,
                    help="codecs mode: client count")
    ap.add_argument("--codec-samples", type=int, default=480,
                    help="codecs mode: total samples")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backends", nargs="+",
                    default=list(BACKEND_VARIANTS),
                    choices=list(BACKEND_VARIANTS))
    ap.add_argument("--bench-out", default="BENCH_engine.json",
                    help="backends mode: write the perf-trajectory JSON "
                         "here (repo root by convention; '' disables)")
    ap.add_argument("--key-batching", type=int, default=1,
                    help="backends mode: re-measure scalar-key scan vs "
                         "batched-key vmap per phase (0 disables)")
    ap.add_argument("--codecs", nargs="+",
                    default=["none", "cast", "int8", "topk"])
    ap.add_argument("--dropouts", nargs="+", type=float,
                    default=[0.0, 0.1, 0.3, 0.5],
                    help="availability mode: post-download dropout rates")
    ap.add_argument("--avail-clients", type=int, default=8,
                    help="availability mode: client count")
    ap.add_argument("--avail-samples", type=int, default=960,
                    help="availability mode: total samples")
    ap.add_argument("--scale-clients", nargs="+", type=int,
                    default=[100, 1000, 10000, 100000, 1000000],
                    help="scale mode: fleet sizes to sweep")
    ap.add_argument("--scale-sampled", type=int, default=16,
                    help="scale mode: participants per round (fixed "
                         "across the sweep)")
    ap.add_argument("--scale-out", default="benchmarks/results/scale.json",
                    help="scale mode: write the full sweep JSON here "
                         "('' disables)")
    ap.add_argument("--obs-out",
                    default="benchmarks/results/obs_rounds.jsonl",
                    help="obs mode: write the telemetry round-event JSONL "
                         "here — one line per generation of the last "
                         "telemetry-on run ('' disables)")
    ap.add_argument("--trajectory-generations", type=int, default=30,
                    help="int8-vs-fp32 trajectory length in codec mode "
                         "(0 disables)")
    ap.add_argument("--out", default=None,
                    help="write the report JSON here "
                         "(e.g. benchmarks/results/codec_compare.json)")
    args = ap.parse_args()

    rep: Dict = {}
    if args.mode in ("backends", "both", "all"):
        rep["backends"] = _run_backend_mode(args)
    if args.mode in ("codecs", "both", "all"):
        rep["codecs"] = _run_codec_mode(args)
    if args.mode in ("availability", "all"):
        rep["availability"] = _run_availability_mode(args)
    if args.mode in ("scale", "all"):
        rep["scale"] = _run_scale_mode(args)
    if args.mode in ("obs", "all"):
        rep["obs"] = _run_obs_mode(args)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rep, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
