"""Shared harness for the paper-shaped federated NAS experiments.

Scaled to this container (16x16 synthetic images, tens of generations) —
the *relative* claims of the paper (RT vs offline cost, Pareto shape,
FLOPs reduction vs the fixed baseline) are what the benchmarks validate;
see DESIGN.md Section 8 for the simulation boundary.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import make_api, nsga2, offline_enas, rt_enas
from repro.core.federated import fedavg_round, make_client_update, \
    make_evaluator, weighted_test_error
from repro.data import make_classification, make_clients, partition_iid, \
    partition_label

IMAGE = 16
RESNET_LIKE_KEY = np.ones(4, dtype=np.int32)   # all-residual master path


def build_clients(num_clients: int, iid: bool, seed: int = 0,
                  n: int = 2000, batch: int = 50, test_batch: int = 50):
    x, y = make_classification(seed, n, image=IMAGE, signal=1.2, noise=0.8)
    if iid:
        shards = partition_iid(seed, n, num_clients)
    else:
        shards = partition_label(seed, y, num_clients, classes_per_client=5)
    return make_clients(x, y, shards, batch=batch, test_batch=test_batch)


def build_api():
    return make_api(get_config("cifar-supernet", smoke=True))


def run_rt(api, clients, generations: int, population: int = 6,
           seed: int = 0, backend: str = "xla") -> Dict:
    rc = rt_enas.RunConfig(population=population, generations=generations,
                           seed=seed, aggregate_backend=backend)
    return rt_enas.run(api, clients, rc)


def run_offline(api, clients, generations: int, population: int = 6,
                seed: int = 0) -> Dict:
    rc = rt_enas.RunConfig(population=population, generations=generations,
                           seed=seed)
    return offline_enas.run(api, clients, rc)


def run_fixed_baseline(api, clients, rounds: int, key=RESNET_LIKE_KEY,
                       seed: int = 0) -> Dict:
    """FedAvg on a fixed architecture (the paper's ResNet18 role)."""
    from repro.optim import round_decay
    params = api.init(jax.random.PRNGKey(seed))
    update = make_client_update(api)
    evaluate = make_evaluator(api)
    jkey = jnp.asarray(key)
    errs = []
    for t in range(rounds):
        lr = float(round_decay(0.1, 0.995, t))
        params = fedavg_round(update, params, jkey, clients, lr)
        errs.append(weighted_test_error(evaluate, params, jkey, clients))
    return {"err": errs, "flops": api.flops(np.asarray(key)),
            "params": params}


def summarize_front(api, hist) -> List[Dict]:
    """Final-generation Pareto front -> [{key, err, flops}] (Fig 8)."""
    objs = hist["objs"][-1]
    sel = nsga2.select(objs, len(hist["parent_keys"][-1]))
    front = nsga2.fast_non_dominated_sort(objs[sel])[0]
    combined_keys = hist["parent_keys"][-1]
    out = []
    for i in front:
        out.append({"err": float(objs[sel][i, 0]),
                    "flops": float(objs[sel][i, 1])})
    out.sort(key=lambda r: r["flops"])
    return out


def save_history(path: str, hist: Dict, extra: Optional[Dict] = None):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    rec = {
        "gen": hist["gen"],
        "best_err": hist["best_err"],
        "knee_err": hist.get("knee_err"),
        "down_gb": hist["down_gb"],
        "up_gb": hist["up_gb"],
        "train_passes": hist["train_passes"],
        "wall_s": hist["wall_s"],
        "final_objs": np.asarray(hist["objs"][-1]).tolist(),
    }
    if extra:
        rec.update(extra)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
