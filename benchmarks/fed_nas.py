"""Shared harness for the paper-shaped federated NAS experiments.

Scaled to this container (16x16 synthetic images, tens of generations) —
the *relative* claims of the paper (RT vs offline cost, Pareto shape,
FLOPs reduction vs the fixed baseline) are what the benchmarks validate;
see DESIGN.md Section 8 for the simulation boundary.

Everything routes through ``repro.engine.FedEngine``; the
``engine_backend`` argument selects the client-execution path ("loop" =
reference per-pair dispatch, "vmap" = ClientBatch-stacked, "mesh" =
population sharded over a jax device mesh).  Run

    PYTHONPATH=src python benchmarks/fed_nas.py

to compare the three backends on the default cross-device config (many
small clients — the axis the loop backend's O(population x clients)
dispatch count scales with).  As a script it forces an 8-way host device
mesh (``--xla_force_host_platform_device_count=8``) so the mesh backend
has devices to shard over; equivalently set XLA_FLAGS yourself.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional

if __name__ == "__main__" and "xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    # must happen before the first jax import; library importers
    # (examples, tests) are left untouched
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8").strip()

import jax
import numpy as np

from repro.configs import get_config
from repro.core import make_api, nsga2
from repro.data import make_classification, make_clients, partition_iid, \
    partition_label
from repro.engine import FedAvgBaseline, FedEngine, OfflineNas, RealTimeNas, \
    RunConfig

IMAGE = 16
RESNET_LIKE_KEY = np.ones(4, dtype=np.int32)   # all-residual master path


def build_clients(num_clients: int, iid: bool, seed: int = 0,
                  n: int = 2000, batch: int = 50, test_batch: int = 50,
                  image: int = IMAGE):
    x, y = make_classification(seed, n, image=image, signal=1.2, noise=0.8)
    if iid:
        shards = partition_iid(seed, n, num_clients)
    else:
        shards = partition_label(seed, y, num_clients, classes_per_client=5)
    return make_clients(x, y, shards, batch=batch, test_batch=test_batch)


def build_api():
    return make_api(get_config("cifar-supernet", smoke=True))


def run_rt(api, clients, generations: int, population: int = 6,
           seed: int = 0, backend: str = "xla",
           engine_backend: str = "loop") -> Dict:
    rc = RunConfig(population=population, generations=generations,
                   seed=seed, aggregate_backend=backend,
                   backend=engine_backend)
    return FedEngine(api, clients, rc,
                     strategy=RealTimeNas()).run().history()


def run_offline(api, clients, generations: int, population: int = 6,
                seed: int = 0, engine_backend: str = "loop") -> Dict:
    rc = RunConfig(population=population, generations=generations,
                   seed=seed, backend=engine_backend)
    return FedEngine(api, clients, rc,
                     strategy=OfflineNas()).run().history()


def run_fixed_baseline(api, clients, rounds: int, key=RESNET_LIKE_KEY,
                       seed: int = 0, engine_backend: str = "loop") -> Dict:
    """FedAvg on a fixed architecture (the paper's ResNet18 role)."""
    rc = RunConfig(generations=rounds, seed=seed, backend=engine_backend)
    res = FedEngine(api, clients, rc,
                    strategy=FedAvgBaseline(key)).run()
    return {"err": [r.best_err for r in res.reports],
            "flops": res.extras["flops"],
            "params": res.extras["params"],
            "stats": res.stats}


def _max_param_diff(a, b) -> float:
    return float(max(
        np.abs(np.asarray(p) - np.asarray(q)).max()
        for p, q in zip(jax.tree.leaves(a.extras["final_master"]),
                        jax.tree.leaves(b.extras["final_master"]))))


def _max_err_diff(a, b) -> float:
    return float(max(
        np.abs(np.asarray(x.objs) - np.asarray(y.objs)).max()
        for x, y in zip(a.reports, b.reports)))


def compare_backends(api=None, clients=None, generations: int = 3,
                     population: int = 6, seed: int = 0,
                     backends=("loop", "vmap", "mesh")) -> Dict:
    """Same search on every execution backend: wall clock, dispatch
    counts, and result agreement (vs the loop reference, plus the
    mesh-vs-vmap pair the sharded path is certified against).  The
    default client set is the cross-device regime (256 small clients)
    where the loop backend's O(population x clients) dispatch count is
    the bottleneck."""
    import dataclasses

    api = api or build_api()
    if clients is None:
        clients = build_clients(256, iid=True, n=2560, batch=5,
                                test_batch=5, image=8)
    out: Dict = {"generations": generations, "population": population,
                 "clients": len(clients), "devices": len(jax.devices()),
                 "backends": list(backends)}
    hists = {}
    for bk in backends:
        eng = FedEngine(api, clients,
                        RunConfig(population=population,
                                  generations=generations, seed=seed,
                                  backend=bk))
        t0 = time.time()
        res = eng.run()
        wall = time.time() - t0
        walls = [r.wall_s for r in res.reports]
        steady = (walls[-1] - walls[-2]) if len(walls) > 1 else walls[-1]
        hists[bk] = res
        out[bk] = {"wall_s": wall, "steady_gen_s": steady,
                   "dispatches": eng.backend.dispatches,
                   "dispatches_per_gen": eng.backend.dispatches / generations}
    ref = hists[backends[0]]
    for bk in backends[1:]:
        out[bk]["max_err_diff"] = _max_err_diff(ref, hists[bk])
        out[bk]["max_param_diff"] = _max_param_diff(ref, hists[bk])
    if "vmap" in hists and "mesh" in hists:
        out["mesh_vs_vmap"] = {
            "comm_stats_equal": dataclasses.asdict(hists["mesh"].stats)
            == dataclasses.asdict(hists["vmap"].stats),
            "max_param_diff": _max_param_diff(hists["vmap"], hists["mesh"]),
            "max_err_diff": _max_err_diff(hists["vmap"], hists["mesh"]),
        }
    if backends[0] == "loop" and "vmap" in hists:  # legacy two-way summary
        out["speedup_total"] = out["loop"]["wall_s"] / out["vmap"]["wall_s"]
        out["speedup_steady"] = (out["loop"]["steady_gen_s"]
                                 / out["vmap"]["steady_gen_s"])
        out["max_err_diff"] = out["vmap"]["max_err_diff"]
        out["max_param_diff"] = out["vmap"]["max_param_diff"]
    return out


def summarize_front(api, hist) -> List[Dict]:
    """Final-generation Pareto front -> [{key, err, flops}] (Fig 8)."""
    objs = hist["objs"][-1]
    sel = nsga2.select(objs, len(hist["parent_keys"][-1]))
    front = nsga2.fast_non_dominated_sort(objs[sel])[0]
    out = []
    for i in front:
        out.append({"err": float(objs[sel][i, 0]),
                    "flops": float(objs[sel][i, 1])})
    out.sort(key=lambda r: r["flops"])
    return out


def save_history(path: str, hist: Dict, extra: Optional[Dict] = None):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    rec = {
        "gen": hist["gen"],
        "best_err": hist["best_err"],
        "knee_err": hist.get("knee_err"),
        "down_gb": hist["down_gb"],
        "up_gb": hist["up_gb"],
        "train_passes": hist["train_passes"],
        "wall_s": hist["wall_s"],
        "final_objs": np.asarray(hist["objs"][-1]).tolist(),
    }
    if extra:
        rec.update(extra)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main():
    import argparse
    ap = argparse.ArgumentParser(
        description="loop vs vmap vs mesh execution-backend comparison")
    ap.add_argument("--generations", type=int, default=3)
    ap.add_argument("--population", type=int, default=6)
    ap.add_argument("--clients", type=int, default=256)
    ap.add_argument("--samples", type=int, default=2560)
    ap.add_argument("--image", type=int, default=8)
    ap.add_argument("--batch", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backends", nargs="+",
                    default=["loop", "vmap", "mesh"],
                    choices=["loop", "vmap", "mesh"])
    args = ap.parse_args()

    clients = build_clients(args.clients, iid=True, n=args.samples,
                            batch=args.batch, test_batch=args.batch,
                            image=args.image)
    rep = compare_backends(build_api(), clients,
                           generations=args.generations,
                           population=args.population, seed=args.seed,
                           backends=tuple(args.backends))
    print(f"{rep['clients']} clients x {rep['generations']} generations, "
          f"population {rep['population']}, {rep['devices']} devices")
    ref = args.backends[0]
    for bk in args.backends:
        r = rep[bk]
        agree = (f" | vs {ref}: err {r['max_err_diff']:.1e} "
                 f"params {r['max_param_diff']:.1e}"
                 if "max_err_diff" in r else "")
        print(f"{bk:>5}: total {r['wall_s']:7.1f}s | steady "
              f"{r['steady_gen_s']:6.2f}s/gen | "
              f"{r['dispatches_per_gen']:7.1f} dispatches/gen{agree}")
    if "speedup_total" in rep:
        print(f"vmap speedup: {rep['speedup_total']:.2f}x total, "
              f"{rep['speedup_steady']:.2f}x steady-state")
    if "mesh_vs_vmap" in rep:
        mv = rep["mesh_vs_vmap"]
        print(f"mesh vs vmap: CommStats equal: {mv['comm_stats_equal']} | "
              f"max err diff {mv['max_err_diff']:.2e} | "
              f"max master-param diff {mv['max_param_diff']:.2e}")


if __name__ == "__main__":
    main()
