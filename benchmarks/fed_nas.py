"""Shared harness for the paper-shaped federated NAS experiments.

Scaled to this container (16x16 synthetic images, tens of generations) —
the *relative* claims of the paper (RT vs offline cost, Pareto shape,
FLOPs reduction vs the fixed baseline) are what the benchmarks validate;
see DESIGN.md Section 8 for the simulation boundary.

Everything routes through ``repro.engine.FedEngine``; the
``engine_backend`` argument selects the client-execution path ("loop" =
reference per-pair dispatch, "vmap" = ClientBatch-stacked, "mesh" =
population sharded over a jax device mesh).  Run

    PYTHONPATH=src python benchmarks/fed_nas.py

to compare the three backends on the default cross-device config (many
small clients — the axis the loop backend's O(population x clients)
dispatch count scales with) AND the payload codecs (``--mode codecs``:
per-codec wire bytes, compression ratio vs fp32, and the int8+error-
feedback vs fp32 search trajectory; ``--out`` writes the JSON that
``benchmarks/results/`` tracks).  As a script it forces an 8-way host
device mesh (``--xla_force_host_platform_device_count=8``) so the mesh
backend has devices to shard over; equivalently set XLA_FLAGS yourself.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional

if __name__ == "__main__" and "xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    # must happen before the first jax import; library importers
    # (examples, tests) are left untouched
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8").strip()

import jax
import numpy as np

from repro.configs import get_config
from repro.core import make_api, nsga2
from repro.data import make_classification, make_clients, partition_iid, \
    partition_label
from repro.engine import FedAvgBaseline, FedEngine, OfflineNas, RealTimeNas, \
    RunConfig

IMAGE = 16
RESNET_LIKE_KEY = np.ones(4, dtype=np.int32)   # all-residual master path


def build_clients(num_clients: int, iid: bool, seed: int = 0,
                  n: int = 2000, batch: int = 50, test_batch: int = 50,
                  image: int = IMAGE):
    x, y = make_classification(seed, n, image=image, signal=1.2, noise=0.8)
    if iid:
        shards = partition_iid(seed, n, num_clients)
    else:
        shards = partition_label(seed, y, num_clients, classes_per_client=5)
    return make_clients(x, y, shards, batch=batch, test_batch=test_batch)


def build_api():
    return make_api(get_config("cifar-supernet", smoke=True))


def run_rt(api, clients, generations: int, population: int = 6,
           seed: int = 0, backend: str = "xla",
           engine_backend: str = "loop") -> Dict:
    rc = RunConfig(population=population, generations=generations,
                   seed=seed, aggregate_backend=backend,
                   backend=engine_backend)
    return FedEngine(api, clients, rc,
                     strategy=RealTimeNas()).run().history()


def run_offline(api, clients, generations: int, population: int = 6,
                seed: int = 0, engine_backend: str = "loop") -> Dict:
    rc = RunConfig(population=population, generations=generations,
                   seed=seed, backend=engine_backend)
    return FedEngine(api, clients, rc,
                     strategy=OfflineNas()).run().history()


def run_fixed_baseline(api, clients, rounds: int, key=RESNET_LIKE_KEY,
                       seed: int = 0, engine_backend: str = "loop") -> Dict:
    """FedAvg on a fixed architecture (the paper's ResNet18 role)."""
    rc = RunConfig(generations=rounds, seed=seed, backend=engine_backend)
    res = FedEngine(api, clients, rc,
                    strategy=FedAvgBaseline(key)).run()
    return {"err": [r.best_err for r in res.reports],
            "flops": res.extras["flops"],
            "params": res.extras["params"],
            "stats": res.stats}


def _max_param_diff(a, b) -> float:
    return float(max(
        np.abs(np.asarray(p) - np.asarray(q)).max()
        for p, q in zip(jax.tree.leaves(a.extras["final_master"]),
                        jax.tree.leaves(b.extras["final_master"]))))


def _max_err_diff(a, b) -> float:
    return float(max(
        np.abs(np.asarray(x.objs) - np.asarray(y.objs)).max()
        for x, y in zip(a.reports, b.reports)))


def compare_backends(api=None, clients=None, generations: int = 3,
                     population: int = 6, seed: int = 0,
                     backends=("loop", "vmap", "mesh")) -> Dict:
    """Same search on every execution backend: wall clock, dispatch
    counts, and result agreement (vs the loop reference, plus the
    mesh-vs-vmap pair the sharded path is certified against).  The
    default client set is the cross-device regime (256 small clients)
    where the loop backend's O(population x clients) dispatch count is
    the bottleneck."""
    import dataclasses

    api = api or build_api()
    if clients is None:
        clients = build_clients(256, iid=True, n=2560, batch=5,
                                test_batch=5, image=8)
    out: Dict = {"generations": generations, "population": population,
                 "clients": len(clients), "devices": len(jax.devices()),
                 "backends": list(backends)}
    hists = {}
    for bk in backends:
        eng = FedEngine(api, clients,
                        RunConfig(population=population,
                                  generations=generations, seed=seed,
                                  backend=bk))
        t0 = time.time()
        res = eng.run()
        wall = time.time() - t0
        walls = [r.wall_s for r in res.reports]
        steady = (walls[-1] - walls[-2]) if len(walls) > 1 else walls[-1]
        hists[bk] = res
        out[bk] = {"wall_s": wall, "steady_gen_s": steady,
                   "dispatches": eng.backend.dispatches,
                   "dispatches_per_gen": eng.backend.dispatches / generations}
    ref = hists[backends[0]]
    for bk in backends[1:]:
        out[bk]["max_err_diff"] = _max_err_diff(ref, hists[bk])
        out[bk]["max_param_diff"] = _max_param_diff(ref, hists[bk])
    if "vmap" in hists and "mesh" in hists:
        out["mesh_vs_vmap"] = {
            "comm_stats_equal": dataclasses.asdict(hists["mesh"].stats)
            == dataclasses.asdict(hists["vmap"].stats),
            "max_param_diff": _max_param_diff(hists["vmap"], hists["mesh"]),
            "max_err_diff": _max_err_diff(hists["vmap"], hists["mesh"]),
        }
    if backends[0] == "loop" and "vmap" in hists:  # legacy two-way summary
        out["speedup_total"] = out["loop"]["wall_s"] / out["vmap"]["wall_s"]
        out["speedup_steady"] = (out["loop"]["steady_gen_s"]
                                 / out["vmap"]["steady_gen_s"])
        out["max_err_diff"] = out["vmap"]["max_err_diff"]
        out["max_param_diff"] = out["vmap"]["max_param_diff"]
    return out


def compare_codecs(api=None, clients=None, generations: int = 3,
                   population: int = 6, seed: int = 0,
                   engine_backend: str = "vmap",
                   codecs=("none", "cast", "int8", "topk")) -> Dict:
    """Same search under every payload codec (applied to both wire
    directions): wire vs fp32-logical bytes, the compression ratio vs
    the ``none`` baseline, and the search-quality cost (final best test
    error vs fp32).  This is the comm trajectory the paper's "reduce the
    local payload" claim asks for — ``benchmarks/results/`` records it
    next to the dispatch counts."""
    api = api or build_api()
    if clients is None:
        clients = build_clients(8, iid=True, n=480, batch=20, test_batch=20)
    out: Dict = {"generations": generations, "population": population,
                 "clients": len(clients), "engine_backend": engine_backend,
                 "codecs": {}}
    codecs = tuple(codecs)
    if codecs[:1] != ("none",):       # the fp32 baseline anchors the ratios
        codecs = ("none",) + tuple(c for c in codecs if c != "none")
    base = None
    for codec in codecs:
        eng = FedEngine(api, clients,
                        RunConfig(population=population,
                                  generations=generations, seed=seed,
                                  backend=engine_backend,
                                  uplink_codec=codec,
                                  downlink_codec=codec))
        t0 = time.time()
        res = eng.run()
        s = res.stats
        rec = {"down_bytes": s.down_bytes, "up_bytes": s.up_bytes,
               "down_wire_bytes": s.down_wire_bytes,
               "up_wire_bytes": s.up_wire_bytes,
               "best_err": float(res.reports[-1].best_err),
               "wall_s": time.time() - t0}
        wire_total = s.down_wire_bytes + s.up_wire_bytes
        if codec == "none":
            base = res
        base_total = (base.stats.down_wire_bytes
                      + base.stats.up_wire_bytes)
        rec["compression_vs_fp32"] = base_total / wire_total
        rec["best_err_delta_vs_fp32"] = (
            rec["best_err"] - float(base.reports[-1].best_err))
        out["codecs"][codec] = rec
    return out


def codec_trajectory(api=None, clients=None, generations: int = 30,
                     population: int = 6, seed: int = 0,
                     codec: str = "int8",
                     engine_backend: str = "vmap") -> Dict:
    """Long-horizon search-quality check: ``codec`` (with the engine's
    server-side error feedback) vs fp32 over ``generations`` rounds on
    the synthetic task.  The acceptance bar is the final best test-error
    rates within 2 points — i.e. compression costs bytes, not search
    quality."""
    api = api or build_api()
    if clients is None:
        clients = build_clients(8, iid=True, n=480, batch=20, test_batch=20)
    runs = {}
    for name, spec in (("fp32", "none"), (codec, codec)):
        res = FedEngine(api, clients,
                        RunConfig(population=population,
                                  generations=generations, seed=seed,
                                  backend=engine_backend,
                                  uplink_codec=spec,
                                  downlink_codec=spec)).run()
        runs[name] = res
    best = {k: [float(r.best_err) for r in v.reports]
            for k, v in runs.items()}
    return {"generations": generations, "codec": codec,
            "best_err": best,
            "final_fp32": best["fp32"][-1], "final_codec": best[codec][-1],
            "final_delta": best[codec][-1] - best["fp32"][-1],
            "wire_ratio": ((runs["fp32"].stats.down_wire_bytes
                            + runs["fp32"].stats.up_wire_bytes)
                           / (runs[codec].stats.down_wire_bytes
                              + runs[codec].stats.up_wire_bytes))}


def summarize_front(api, hist) -> List[Dict]:
    """Final-generation Pareto front -> [{key, err, flops}] (Fig 8)."""
    objs = hist["objs"][-1]
    sel = nsga2.select(objs, len(hist["parent_keys"][-1]))
    front = nsga2.fast_non_dominated_sort(objs[sel])[0]
    out = []
    for i in front:
        out.append({"err": float(objs[sel][i, 0]),
                    "flops": float(objs[sel][i, 1])})
    out.sort(key=lambda r: r["flops"])
    return out


def save_history(path: str, hist: Dict, extra: Optional[Dict] = None):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    rec = {
        "gen": hist["gen"],
        "best_err": hist["best_err"],
        "knee_err": hist.get("knee_err"),
        "down_gb": hist["down_gb"],
        "up_gb": hist["up_gb"],
        "train_passes": hist["train_passes"],
        "wall_s": hist["wall_s"],
        "final_objs": np.asarray(hist["objs"][-1]).tolist(),
    }
    if extra:
        rec.update(extra)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def _run_backend_mode(args) -> Dict:
    clients = build_clients(args.clients, iid=True, n=args.samples,
                            batch=args.batch, test_batch=args.batch,
                            image=args.image)
    rep = compare_backends(build_api(), clients,
                           generations=args.generations,
                           population=args.population, seed=args.seed,
                           backends=tuple(args.backends))
    print(f"{rep['clients']} clients x {rep['generations']} generations, "
          f"population {rep['population']}, {rep['devices']} devices")
    ref = args.backends[0]
    for bk in args.backends:
        r = rep[bk]
        agree = (f" | vs {ref}: err {r['max_err_diff']:.1e} "
                 f"params {r['max_param_diff']:.1e}"
                 if "max_err_diff" in r else "")
        print(f"{bk:>5}: total {r['wall_s']:7.1f}s | steady "
              f"{r['steady_gen_s']:6.2f}s/gen | "
              f"{r['dispatches_per_gen']:7.1f} dispatches/gen{agree}")
    if "speedup_total" in rep:
        print(f"vmap speedup: {rep['speedup_total']:.2f}x total, "
              f"{rep['speedup_steady']:.2f}x steady-state")
    if "mesh_vs_vmap" in rep:
        mv = rep["mesh_vs_vmap"]
        print(f"mesh vs vmap: CommStats equal: {mv['comm_stats_equal']} | "
              f"max err diff {mv['max_err_diff']:.2e} | "
              f"max master-param diff {mv['max_param_diff']:.2e}")
    return rep


def _run_codec_mode(args) -> Dict:
    api = build_api()
    clients = build_clients(args.codec_clients, iid=True,
                            n=args.codec_samples, batch=20, test_batch=20)
    rep = compare_codecs(api, clients, generations=args.generations,
                         population=args.population, seed=args.seed,
                         codecs=tuple(args.codecs))
    print(f"\ncodecs ({rep['clients']} clients x {rep['generations']} "
          f"generations, population {rep['population']}, "
          f"{rep['engine_backend']} backend):")
    for codec, r in rep["codecs"].items():
        print(f"{codec:>6}: down {r['down_wire_bytes'] / 1e6:8.2f} MB | "
              f"up {r['up_wire_bytes'] / 1e6:8.2f} MB | "
              f"{r['compression_vs_fp32']:5.2f}x vs fp32 | "
              f"best err {r['best_err']:.3f} "
              f"({r['best_err_delta_vs_fp32']:+.3f})")
    if args.trajectory_generations > 0:
        traj = codec_trajectory(api, clients,
                                generations=args.trajectory_generations,
                                population=args.population, seed=args.seed)
        rep["trajectory"] = traj
        print(f"{traj['codec']}+EF vs fp32 over "
              f"{traj['generations']} generations: final err "
              f"{traj['final_codec']:.3f} vs {traj['final_fp32']:.3f} "
              f"(delta {traj['final_delta']:+.3f}) at "
              f"{traj['wire_ratio']:.2f}x fewer wire bytes")
    return rep


def main():
    import argparse
    ap = argparse.ArgumentParser(
        description="execution-backend and payload-codec comparisons")
    ap.add_argument("--mode", choices=["backends", "codecs", "both"],
                    default="both")
    ap.add_argument("--generations", type=int, default=3)
    ap.add_argument("--population", type=int, default=6)
    ap.add_argument("--clients", type=int, default=256,
                    help="backends mode: client count (the codec mode "
                         "has its own --codec-* sizing)")
    ap.add_argument("--samples", type=int, default=2560,
                    help="backends mode: total samples")
    ap.add_argument("--image", type=int, default=8,
                    help="backends mode: image size")
    ap.add_argument("--batch", type=int, default=5,
                    help="backends mode: per-client batch size")
    ap.add_argument("--codec-clients", type=int, default=8,
                    help="codecs mode: client count")
    ap.add_argument("--codec-samples", type=int, default=480,
                    help="codecs mode: total samples")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backends", nargs="+",
                    default=["loop", "vmap", "mesh"],
                    choices=["loop", "vmap", "mesh"])
    ap.add_argument("--codecs", nargs="+",
                    default=["none", "cast", "int8", "topk"])
    ap.add_argument("--trajectory-generations", type=int, default=30,
                    help="int8-vs-fp32 trajectory length in codec mode "
                         "(0 disables)")
    ap.add_argument("--out", default=None,
                    help="write the report JSON here "
                         "(e.g. benchmarks/results/codec_compare.json)")
    args = ap.parse_args()

    rep: Dict = {}
    if args.mode in ("backends", "both"):
        rep["backends"] = _run_backend_mode(args)
    if args.mode in ("codecs", "both"):
        rep["codecs"] = _run_codec_mode(args)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rep, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
