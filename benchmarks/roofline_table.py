"""Aggregate the dry-run JSON records into the §Roofline table.

Reads benchmarks/results/dryrun_*.json (written by launch/dryrun.py --save)
and emits a markdown table: three roofline terms, dominant bottleneck,
MODEL_FLOPS/HLO ratio and a one-line 'what would move the dominant term'
note per (arch x shape).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

ADVICE = {
    ("memory", "train"): "fuse attention (Pallas flash) to stop spilling "
                         "fp32 scores to HBM; bigger microbatch splits",
    ("memory", "prefill"): "flash-attention kernel (scores stay in VMEM)",
    ("memory", "decode"): "batch more requests per step to amortize the "
                          "weight sweep (decode reads all params per token)",
    ("compute", "train"): "reduce remat recompute (checkpoint every 2nd "
                          "layer); MXU-align matmul dims",
    ("compute", "prefill"): "MXU-align head dims; overlap collectives",
    ("compute", "decode"): "speculative/multi-token decoding",
    ("collective", "train"): "reduce-scatter grads instead of all-reduce; "
                             "overlap collectives with compute",
    ("collective", "prefill"): "shard kv-seq instead of heads to cut "
                               "all-gathers",
    ("collective", "decode"): "replicate small weights; fold pod axis into "
                              "data to shorten all-reduce chains",
}


def analytic_hbm_floor_s(rec: Dict) -> float:
    """Minimum HBM traffic per step per chip, from first principles —
    the counterweight to XLA:CPU's inflated 'bytes accessed' (which counts
    every unfused elementwise op).  Weights/optimizer are read/written
    once per step; activations are written+read once per layer.
    """
    from repro.configs import get_config, get_shape
    from repro.core import flops as fl

    cfg = get_config(rec["arch"])
    shape = get_shape(rec["shape"])
    chips = rec.get("chips", 256)
    w = fl.model_params(cfg) * 2                    # bf16 weights
    w_active = fl.model_params(cfg, active_only=True) * 2
    toks = shape.global_batch * shape.seq_len
    act = 2 * 2 * toks * cfg.d_model * cfg.num_layers  # write+read, bf16
    if shape.kind == "train":
        total = 4 * w + 2 * act                     # w+grad+mom r/w, remat 2x
    elif shape.kind == "prefill":
        total = w + act
    else:  # decode: every active weight + the whole cache per token
        cl = min(shape.seq_len, cfg.sliding_window) if shape.sliding \
            else shape.seq_len
        if cfg.family in ("ssm",):
            cache = (shape.global_batch * cfg.ssm_heads * cfg.ssm_head_dim
                     * cfg.ssm_state * 4 * cfg.num_layers)
        else:
            cache = (shape.global_batch * cl * cfg.num_kv_heads * cfg.hd
                     * 2 * 2 * cfg.num_layers)
        total = w_active + cache
    return total / chips / 819e9


def load_records(mesh: str = "16x16", tag: str = "") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "dryrun_*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("mesh") == mesh and r.get("tag", "") == tag \
                and not r.get("supernet"):
            recs.append(r)
    return recs


def fmt_table(recs: List[Dict]) -> str:
    head = ("| arch | shape | compute ms | memory ms (XLA:CPU) | "
            "mem floor ms | collective ms | bound | bound(floor) | "
            "MODEL/HLO | temp GB/dev |\n"
            "|---|---|---|---|---|---|---|---|---|---|")
    rows = [head]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for r in sorted(recs, key=lambda r: (r["arch"], order.get(r["shape"], 9))):
        if "compute_s" not in r:
            continue
        floor = analytic_hbm_floor_s(r)
        bound_floor = max(
            ("compute", r["compute_s"]), ("memory", floor),
            ("collective", r["collective_s"]), key=lambda kv: kv[1])[0]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.2f} | "
            f"{r['memory_s']*1e3:.2f} | {floor*1e3:.2f} | "
            f"{r['collective_s']*1e3:.2f} | "
            f"{r['dominant']} | {bound_floor} | "
            f"{r.get('useful_flops_ratio', 0):.2f} | "
            f"{r.get('temp_size_in_bytes', 0)/1e9:.1f} |")
    return "\n".join(rows)


def advice_lines(recs: List[Dict]) -> List[str]:
    out = []
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if "dominant" not in r:
            continue
        key = (r["dominant"], r["kind"])
        out.append(f"- {r['arch']} x {r['shape']}: {r['dominant']}-bound -> "
                   f"{ADVICE.get(key, 'profile further')}")
    return out


def main() -> None:
    recs = load_records()
    print(fmt_table(recs))
    print()
    counts = {}
    for r in recs:
        counts[r.get("dominant", "?")] = counts.get(r.get("dominant", "?"), 0) + 1
    print("dominant-term histogram:", counts)


if __name__ == "__main__":
    main()
